//! Property-based test suites over the coordinator's pure logic:
//! sampling/verification invariants, data-plane round-trips, batching
//! policy, grammar guarantees. These run without PJRT or artifacts.

use lk_spec::data::corpus::Dataset;
use lk_spec::data::grammar::{Domain, DOMAINS};
use lk_spec::data::vocab::{build_vocab_map, invert_vocab_map};
use lk_spec::server::batcher::{Batcher, BatcherConfig};
use lk_spec::server::http::parse::{HttpRequest, ParseError, ParseLimits, RequestParser};
use lk_spec::server::kv::{copy_row, gather_rows};
use lk_spec::spec::accept::AcceptanceStats;
use lk_spec::spec::gradients;
use lk_spec::spec::sampling::{
    acceptance_rate, argmax_rank, categorical_from_uniform, sample_categorical, softmax_t,
    verify_round, verify_token, verify_tree, RoundUniforms, SamplingMode, TreeSpec, Verdict,
};
use lk_spec::tensor::{read_checkpoint, write_checkpoint, Checkpoint, DType, HostTensor};
use lk_spec::util::proptest::{forall, gen};
use lk_spec::util::{Json, Pcg64};

// ---------------------------------------------------------------------------
// speculative sampling invariants
// ---------------------------------------------------------------------------

/// THE theorem (Leviathan et al. 2023, Thm. 1): for arbitrary (p, q) the
/// accept-or-resample procedure outputs exactly p. Checked empirically
/// across random distribution pairs of varied sharpness and size.
#[test]
fn prop_rejection_sampling_is_lossless() {
    forall(
        "rejection sampling preserves p",
        0xA11CE,
        8,
        |rng| {
            let v = [4, 8, 16, 48][rng.below(4)];
            let sharp_p = 1.0 + rng.uniform() * 3.0;
            let sharp_q = 1.0 + rng.uniform() * 3.0;
            let p = gen::dist(rng, v, sharp_p);
            let q = gen::dist(rng, v, sharp_q);
            (p, q, rng.next_u64())
        },
        |(p, q, seed)| {
            let v = p.len();
            let n = 120_000;
            let mut rng = Pcg64::new(*seed, 1);
            let mut counts = vec![0f64; v];
            for _ in 0..n {
                let x = sample_categorical(&mut rng, q);
                match verify_token(&mut rng, p, q, x, SamplingMode::Stochastic) {
                    Verdict::Accept => counts[x] += 1.0,
                    Verdict::Reject { replacement } => counts[replacement as usize] += 1.0,
                }
            }
            for i in 0..v {
                let emp = counts[i] / n as f64;
                let tol = 0.012 + 3.0 * (p[i] as f64 / n as f64).sqrt();
                if (emp - p[i] as f64).abs() > tol {
                    return Err(format!("token {i}: |{emp:.4} - {:.4}| > {tol:.4}", p[i]));
                }
            }
            Ok(())
        },
    );
}

/// The fused fixed-uniform round (the contract the device kernel and the
/// host fallback share) is ALSO lossless: for arbitrary (p, q) a k=1
/// round emits exactly p, with drafts drawn through the same
/// explicit-uniform inverse CDF the device entries use.
#[test]
fn prop_fused_verify_round_is_lossless() {
    forall(
        "fused verify_round preserves p",
        0xFA57,
        6,
        |rng| {
            let v = [4, 8, 16, 48][rng.below(4)];
            let sharp_p = 1.0 + rng.uniform() * 3.0;
            let sharp_q = 1.0 + rng.uniform() * 3.0;
            let p = gen::dist(rng, v, sharp_p);
            let q = gen::dist(rng, v, sharp_q);
            let bonus = gen::dist(rng, v, 2.0);
            (p, q, bonus, rng.next_u64())
        },
        |(p, q, bonus, seed)| {
            let v = p.len();
            let mut p_rows = p.clone();
            p_rows.extend_from_slice(bonus);
            let n = 120_000;
            let mut rng = Pcg64::new(*seed, 3);
            let mut counts = vec![0f64; v];
            for _ in 0..n {
                let x = categorical_from_uniform(q, rng.uniform() as f32) as i32;
                let u = RoundUniforms::draw(&mut rng, 1, SamplingMode::Stochastic);
                let rv = verify_round(1, v, &p_rows, q, &[x], SamplingMode::Stochastic, &u);
                let emitted = if rv.n_accepted == 1 { x } else { rv.token };
                counts[emitted as usize] += 1.0;
            }
            for i in 0..v {
                let emp = counts[i] / n as f64;
                let tol = 0.012 + 3.0 * (p[i] as f64 / n as f64).sqrt();
                if (emp - p[i] as f64).abs() > tol {
                    return Err(format!("token {i}: |{emp:.4} - {:.4}| > {tol:.4}", p[i]));
                }
            }
            Ok(())
        },
    );
}

/// Acceptance rate of the fused round matches alpha = sum min(p, q),
/// and the accept chain never runs past the first rejection.
#[test]
fn prop_fused_round_acceptance_equals_alpha() {
    forall(
        "fused round E[accept] == alpha",
        0xFA58,
        6,
        |rng| {
            let v = [8, 32, 128][rng.below(3)];
            (
                gen::dist(rng, v, 2.0),
                gen::dist(rng, v, 2.0),
                rng.next_u64(),
            )
        },
        |(p, q, seed)| {
            let v = p.len();
            let alpha = acceptance_rate(p, q);
            let mut p_rows = p.clone();
            p_rows.extend_from_slice(p); // bonus row, never counted
            let mut rng = Pcg64::new(*seed, 4);
            let n = 80_000;
            let mut acc = 0u64;
            for _ in 0..n {
                let x = categorical_from_uniform(q, rng.uniform() as f32) as i32;
                let u = RoundUniforms::draw(&mut rng, 1, SamplingMode::Stochastic);
                let rv = verify_round(1, v, &p_rows, q, &[x], SamplingMode::Stochastic, &u);
                acc += rv.n_accepted as u64;
            }
            let emp = acc as f64 / n as f64;
            if (emp - alpha).abs() > 0.015 {
                return Err(format!("empirical {emp:.4} vs alpha {alpha:.4}"));
            }
            Ok(())
        },
    );
}

/// THE tree-degeneration property (ISSUE-3 acceptance criterion): a
/// single-chain topology run through the multi-candidate rule
/// reproduces `verify_round` verdicts EXACTLY — same uniforms in, same
/// accepted prefix and same emitted token out, bit-for-bit, in every
/// sampling mode. (The host-vs-device half of the parity triangle is
/// pinned by python/tests/test_tree_verify.py over the same
/// formulations.)
#[test]
fn prop_tree_chain_degenerates_to_verify_round() {
    forall(
        "chain TreeSpec == verify_round",
        0x7EE5,
        48,
        |rng| {
            let v = [4, 8, 16, 48][rng.below(4)];
            let k = 1 + rng.below(7);
            let mode = [
                SamplingMode::Stochastic,
                SamplingMode::Greedy,
                SamplingMode::GreedyDraft,
            ][rng.below(3)];
            let mut p = Vec::new();
            for _ in 0..=k {
                p.extend(gen::dist(rng, v, 1.0 + rng.uniform() * 3.0));
            }
            let mut q = Vec::new();
            let mut drafted = Vec::new();
            for _ in 0..k {
                let qi = gen::dist(rng, v, 1.0 + rng.uniform() * 3.0);
                drafted.push(sample_categorical(&mut Pcg64::new(rng.next_u64(), 0), &qi) as i32);
                q.extend(qi);
            }
            let u = RoundUniforms {
                accept: (0..k).map(|_| rng.uniform() as f32).collect(),
                sample: rng.uniform() as f32,
            };
            (k, v, p, q, drafted, u, mode)
        },
        |(k, v, p, q, drafted, u, mode)| {
            let rv = verify_round(*k, *v, p, q, drafted, *mode, u);
            let tv = verify_tree(&TreeSpec::chain(*k), *v, p, q, drafted, *mode, u);
            if tv.path.len() != rv.n_accepted {
                return Err(format!(
                    "path len {} != n_accepted {}",
                    tv.path.len(),
                    rv.n_accepted
                ));
            }
            if tv.path != (0..rv.n_accepted).collect::<Vec<_>>() {
                return Err(format!("path {:?} not the prefix", tv.path));
            }
            if tv.token != rv.token {
                return Err(format!("token {} != {}", tv.token, rv.token));
            }
            Ok(())
        },
    );
}

/// Structural invariants of the tree walk on arbitrary fanout
/// topologies: the accepted path is a root-to-node chain (one node per
/// level, each the parent of the next), never deeper than the tree, and
/// the emission is a valid token id.
#[test]
fn prop_tree_verify_path_is_root_chain() {
    forall(
        "tree verdict structurally valid",
        0x7EE6,
        48,
        |rng| {
            let v = [4, 8, 16][rng.below(3)];
            let fanout: Vec<usize> = (0..1 + rng.below(2)).map(|_| 1 + rng.below(2)).collect();
            let tree = TreeSpec::from_fanout(&fanout).unwrap();
            let n = tree.len();
            let mode = [
                SamplingMode::Stochastic,
                SamplingMode::Greedy,
                SamplingMode::GreedyDraft,
            ][rng.below(3)];
            let mut p = Vec::new();
            for _ in 0..=n {
                p.extend(gen::dist(rng, v, 2.0));
            }
            let mut q = Vec::new();
            let mut drafted = Vec::new();
            for _ in 0..n {
                let qi = gen::dist(rng, v, 2.0);
                drafted.push(categorical_from_uniform(&qi, rng.uniform() as f32) as i32);
                q.extend(qi);
            }
            let u = RoundUniforms {
                accept: (0..n).map(|_| rng.uniform() as f32).collect(),
                sample: rng.uniform() as f32,
            };
            (tree, v, p, q, drafted, u, mode)
        },
        |(tree, v, p, q, drafted, u, mode)| {
            let tv = verify_tree(tree, *v, p, q, drafted, *mode, u);
            if tv.path.len() > tree.depth() {
                return Err(format!("path {} deeper than {}", tv.path.len(), tree.depth()));
            }
            let mut prev: i32 = -1;
            for (lvl, &node) in tv.path.iter().enumerate() {
                if tree.level(node) != lvl {
                    return Err(format!("node {node} at level {} != {lvl}", tree.level(node)));
                }
                if tree.parent(node) != prev {
                    return Err(format!("node {node} parent {} != {prev}", tree.parent(node)));
                }
                prev = node as i32;
            }
            if !(0..*v as i32).contains(&tv.token) {
                return Err(format!("token {} out of range", tv.token));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_acceptance_equals_alpha() {
    forall(
        "E[accept] == sum min(p,q)",
        0xBEE,
        6,
        |rng| {
            let v = [8, 32, 128][rng.below(3)];
            (
                gen::dist(rng, v, 2.0),
                gen::dist(rng, v, 2.0),
                rng.next_u64(),
            )
        },
        |(p, q, seed)| {
            let alpha = acceptance_rate(p, q);
            let mut rng = Pcg64::new(*seed, 2);
            let n = 80_000;
            let mut acc = 0u64;
            for _ in 0..n {
                let x = sample_categorical(&mut rng, q);
                if matches!(
                    verify_token(&mut rng, p, q, x, SamplingMode::Stochastic),
                    Verdict::Accept
                ) {
                    acc += 1;
                }
            }
            let emp = acc as f64 / n as f64;
            if (emp - alpha).abs() > 0.015 {
                return Err(format!("empirical {emp:.4} vs alpha {alpha:.4}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_greedy_draft_never_beats_exact() {
    // Appendix D: with q == p the exact rule accepts at rate 1 while the
    // greedy-draft bug accepts at only max(p).
    forall(
        "greedy-draft <= exact when q=p",
        0xD00D,
        32,
        |rng| {
            let sharp = 1.0 + rng.uniform() * 4.0;
            gen::dist(rng, 32, sharp)
        },
        |p| {
            let exact = acceptance_rate(p, p); // == 1
            let greedy =
                *p.iter().max_by(|a, b| a.partial_cmp(b).unwrap()).unwrap() as f64;
            if greedy <= exact + 1e-6 {
                Ok(())
            } else {
                Err(format!("{greedy} > {exact}"))
            }
        },
    );
}

#[test]
fn prop_softmax_t_temperature_ordering() {
    forall(
        "lower T concentrates mass on argmax",
        0x7E4,
        64,
        |rng| gen::f32s(rng, 24, 2.0),
        |logits| {
            let p1 = softmax_t(logits, 1.0);
            let p05 = softmax_t(logits, 0.5);
            let am = lk_spec::spec::sampling::argmax(logits);
            let s1: f32 = p1.iter().sum();
            if (s1 - 1.0).abs() > 1e-5 {
                return Err(format!("not normalized: {s1}"));
            }
            if p05[am] < p1[am] - 1e-6 {
                return Err(format!("T=0.5 mass {} < T=1 {}", p05[am], p1[am]));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tau_bounds() {
    // τ ∈ [1, K+1]; merge == concat.
    forall(
        "tau within bounds and merge-consistent",
        0x7A0,
        64,
        |rng| {
            let k = 1 + rng.below(7);
            let rounds: Vec<(usize, usize)> = (0..1 + rng.below(30))
                .map(|_| {
                    let d = 1 + rng.below(k);
                    (d, rng.below(d + 1))
                })
                .collect();
            (k, rounds)
        },
        |(k, rounds)| {
            let mut a = AcceptanceStats::new(*k);
            let mut b = AcceptanceStats::new(*k);
            let mut whole = AcceptanceStats::new(*k);
            for (i, &(d, acc)) in rounds.iter().enumerate() {
                whole.record_round(d, acc);
                if i % 2 == 0 {
                    a.record_round(d, acc)
                } else {
                    b.record_round(d, acc)
                }
            }
            a.merge(&b);
            if (a.tau() - whole.tau()).abs() > 1e-12 {
                return Err("merge != concat".into());
            }
            if whole.tau() < 1.0 - 1e-12 || whole.tau() > *k as f64 + 1.0 + 1e-12 {
                return Err(format!("tau {} out of bounds", whole.tau()));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// closed-form gradients vs finite differences (random regimes)
// ---------------------------------------------------------------------------

#[test]
fn prop_gradients_match_finite_differences() {
    forall(
        "closed forms == FD over random logits",
        0x96AD,
        10,
        |rng| (gen::f32s(rng, 16, 2.0), gen::f32s(rng, 16, 1.0)),
        |(zp, zq)| {
            let p = softmax_t(zp, 1.0);
            let q = softmax_t(zq, 1.0);
            let analytic = gradients::grad_kl(&p, &q);
            let eps = 1e-3f32;
            for j in 0..zq.len() {
                let mut zp_ = zq.clone();
                zp_[j] += eps;
                let mut zm_ = zq.clone();
                zm_[j] -= eps;
                let fd = (gradients::kl_loss(&p, &softmax_t(&zp_, 1.0))
                    - gradients::kl_loss(&p, &softmax_t(&zm_, 1.0)))
                    / (2.0 * eps as f64);
                if (fd - analytic[j] as f64).abs() > 5e-3 {
                    return Err(format!("kl grad[{j}]: fd {fd} vs {}", analytic[j]));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// data plane round-trips
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
        3 => {
            let n = rng.below(12);
            Json::Str(
                (0..n)
                    .map(|_| char::from_u32(0x20 + rng.below(0x250) as u32).unwrap_or('x'))
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    forall(
        "json parse(serialize(v)) == v",
        0x15DA,
        128,
        |rng| random_json(rng, 3),
        |v| {
            let s = v.to_string();
            let back = Json::parse(&s).map_err(|e| e.to_string())?;
            if &back != v {
                return Err(format!("{s} -> {back:?}"));
            }
            let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
            if &pretty != v {
                return Err("pretty roundtrip differs".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_checkpoint_roundtrip() {
    let dir = std::env::temp_dir().join(format!("lk_prop_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    forall(
        "checkpoint write/read identity",
        0xC4C4,
        24,
        |rng| {
            let n_tensors = 1 + rng.below(5);
            let tensors: Vec<(String, HostTensor)> = (0..n_tensors)
                .map(|i| {
                    let rank = rng.below(4);
                    let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(6)).collect();
                    let n: usize = shape.iter().product();
                    let t = match rng.below(3) {
                        0 => HostTensor::from_f32(&shape, &gen::f32s(rng, n, 10.0)),
                        1 => HostTensor::from_i32(&shape, &gen::tokens(rng, n, 1000)),
                        _ => HostTensor::from_u32(
                            &shape,
                            &(0..n).map(|_| rng.next_u32()).collect::<Vec<_>>(),
                        ),
                    };
                    (format!("t/{i}"), t)
                })
                .collect();
            (tensors, rng.next_u64())
        },
        |(tensors, salt)| {
            let mut c = Checkpoint::new(Json::obj(vec![("salt", Json::Num(*salt as f64))]));
            for (name, t) in tensors {
                c.tensors.insert(name.clone(), t.clone());
            }
            let path = dir.join(format!("{salt:x}.lkt"));
            write_checkpoint(&path, &c).map_err(|e| e.to_string())?;
            let back = read_checkpoint(&path).map_err(|e| e.to_string())?;
            std::fs::remove_file(&path).ok();
            if back.tensors.len() != tensors.len() {
                return Err("tensor count".into());
            }
            for (name, t) in tensors {
                if back.tensors.get(name) != Some(t) {
                    return Err(format!("tensor '{name}' differs"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_copy_row_identity() {
    forall(
        "copy_row moves exactly one row",
        0xF0F0,
        48,
        |rng| {
            let rank = 2 + rng.below(3);
            let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(5)).collect();
            let axis = rng.below(rank);
            let n: usize = shape.iter().product();
            (shape.clone(), axis, gen::f32s(rng, n, 1.0))
        },
        |(shape, axis, data)| {
            let src = HostTensor::from_f32(shape, data);
            let mut dst = HostTensor::zeros(DType::F32, shape);
            let b = shape[*axis];
            let src_b = b / 2;
            copy_row(&mut dst, 0, &src, src_b, *axis).map_err(|e| e.to_string())?;
            let sv = src.as_f32();
            let dv = dst.as_f32();
            let outer: usize = shape[..*axis].iter().product();
            let inner: usize = shape[*axis + 1..].iter().product();
            for o in 0..outer {
                for i in 0..inner {
                    let d0 = dv[(o * b) * inner + i];
                    let s0 = sv[(o * b + src_b) * inner + i];
                    if d0 != s0 {
                        return Err(format!("row copy mismatch at ({o},{i})"));
                    }
                    for r in 1..b {
                        if dv[(o * b + r) * inner + i] != 0.0 {
                            return Err(format!("row {r} polluted"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// The paged-migration exactness contract: `gather_rows` (the host
/// reference of the lowered `kv_gather_rows_b{Bsrc}x{Bdst}` entries)
/// agrees BIT-FOR-BIT with a per-row `copy_row` loop — arbitrary shapes
/// and axes, row maps with repeats (upshift padding clones), and the
/// serve-bucket pairs (1,4)/(4,1) the scheduler actually lowers.
#[test]
fn prop_gather_rows_equals_copy_row_loop() {
    forall(
        "gather_rows == copy_row per dst row",
        0x6A7E,
        48,
        |rng| {
            let rank = 2 + rng.below(4);
            let mut shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(5)).collect();
            let axis = rng.below(rank);
            // Bias half the cases to the lowered bucket pairs on the
            // real batch axes: (src 1 -> dst 4) and (src 4 -> dst 1).
            if rng.below(2) == 0 {
                shape[axis] = [1, 4][rng.below(2)];
            }
            let src_b = shape[axis];
            let dst_b = 1 + rng.below(5);
            let row_map: Vec<usize> = (0..dst_b).map(|_| rng.below(src_b)).collect();
            let n: usize = shape.iter().product();
            (shape.clone(), axis, row_map, gen::f32s(rng, n, 1e3))
        },
        |(shape, axis, row_map, data)| {
            let src = HostTensor::from_f32(shape, data);
            let gathered = gather_rows(&src, row_map, *axis).map_err(|e| e.to_string())?;
            let mut dst_shape = shape.clone();
            dst_shape[*axis] = row_map.len();
            let mut reference = HostTensor::zeros(DType::F32, &dst_shape);
            for (dst_row, &src_row) in row_map.iter().enumerate() {
                copy_row(&mut reference, dst_row, &src, src_row, *axis)
                    .map_err(|e| e.to_string())?;
            }
            if gathered.shape != reference.shape {
                return Err(format!(
                    "shape {:?} != {:?}",
                    gathered.shape, reference.shape
                ));
            }
            if gathered.data != reference.data {
                return Err(format!(
                    "bytes differ for map {row_map:?} on axis {axis} of {shape:?}"
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// grammars & vocab
// ---------------------------------------------------------------------------

#[test]
fn prop_grammars_deterministic_and_in_range() {
    forall(
        "domain docs reproducible, ids in range, EOS-terminated",
        0x94A2,
        36,
        |rng| (DOMAINS[rng.below(3)], rng.next_u64(), 60 + rng.below(200)),
        |(domain, seed, len)| {
            let a = domain.generate(&mut Pcg64::new(*seed, 5), *len);
            let b = domain.generate(&mut Pcg64::new(*seed, 5), *len);
            if a != b {
                return Err("non-deterministic".into());
            }
            if *a.last().unwrap() != lk_spec::data::EOS {
                return Err("missing EOS".into());
            }
            if a.len() < *len {
                return Err(format!("too short: {} < {len}", a.len()));
            }
            for &t in &a[..a.len() - 1] {
                if !(lk_spec::data::FIRST_CONTENT..lk_spec::data::VOCAB as i32).contains(&t) {
                    return Err(format!("token {t} out of range"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_vocab_map_invariants() {
    forall(
        "vocab map sorted/unique/invertible, coverage monotone",
        0x10CA,
        12,
        |rng| {
            let mut tokens = Vec::new();
            let domain = DOMAINS[rng.below(3)];
            for _ in 0..6 {
                tokens.extend(domain.generate(rng, 200));
            }
            tokens
        },
        |tokens| {
            let ds = Dataset {
                domain: Domain::Chat,
                tokens: tokens.clone(),
            };
            let dss = std::slice::from_ref(&ds);
            let (m1, c1) = build_vocab_map(dss, 512, 128);
            let (m2, c2) = build_vocab_map(dss, 512, 320);
            if !(m1.windows(2).all(|w| w[0] < w[1]) && m2.windows(2).all(|w| w[0] < w[1])) {
                return Err("not sorted/unique".into());
            }
            if c2 < c1 - 1e-12 {
                return Err(format!("coverage not monotone: {c1} > {c2}"));
            }
            let inv = invert_vocab_map(&m2, 512);
            for (i, &f) in m2.iter().enumerate() {
                if inv[f as usize] != Some(i as u16) {
                    return Err("inverse map broken".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// batcher policy
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_never_exceeds_bucket_and_preserves_order() {
    forall(
        "batcher FIFO + bucket cap",
        0xBA7C,
        64,
        |rng| 1 + rng.below(40),
        |n| {
            let mut b = Batcher::new(BatcherConfig {
                buckets: vec![1, 4],
                max_wait: std::time::Duration::ZERO,
                queue_cap: 1024,
            });
            for i in 0..*n {
                b.push(i).map_err(|_| "push rejected".to_string())?;
            }
            let mut seen = Vec::new();
            while let Some(g) = b.next_group(std::time::Instant::now()) {
                if g.len() > 4 {
                    return Err(format!("group of {} > bucket 4", g.len()));
                }
                seen.extend(g);
            }
            if seen != (0..*n).collect::<Vec<_>>() {
                return Err("order not preserved".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// adaptive speculation (per-round K schedules)
// ---------------------------------------------------------------------------

/// Prefix-deterministic synthetic model: the distribution at a position
/// is a pure function of (salt, token prefix) — the structure the
/// engine's draft/target models share along the accepted path (same
/// prefix -> same distribution, wherever round boundaries fall). This
/// is the substrate the adaptive-K exactness properties run on.
fn synth_dist(salt: u64, prefix: &[i32], vocab: usize, sharp: f64) -> Vec<f32> {
    let mut h = salt;
    for &t in prefix {
        h = h
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(t as u64 + 1);
    }
    let mut rng = Pcg64::new(h, 0x5EED);
    gen::dist(&mut rng, vocab, sharp)
}

/// Decode `len` tokens through engine-shaped rounds over the synthetic
/// model: each round asks `next_k` for its chain length, drafts that
/// many tokens from the q-model (chained on the speculated prefix),
/// verifies through the audited `verify_round`, and reports the round's
/// (k, n_accepted) to `observe` (how a controller stays in the loop).
/// Stochastic draws come from `rng` under the fixed-uniform contract:
/// k draft draws + k accept draws + one sample draw per round.
#[allow(clippy::too_many_arguments)]
fn decode_schedule(
    psalt: u64,
    qsalt: u64,
    vocab: usize,
    len: usize,
    mode: SamplingMode,
    rng: &mut Pcg64,
    mut next_k: impl FnMut(usize) -> usize,
    mut observe: impl FnMut(usize, usize),
) -> (Vec<i32>, usize) {
    use lk_spec::spec::sampling::argmax;
    let mut out: Vec<i32> = Vec::new();
    let mut rounds = 0usize;
    while out.len() < len {
        let k = next_k(rounds).clamp(1, 7);
        let mut drafts: Vec<i32> = Vec::with_capacity(k);
        let mut q_rows: Vec<f32> = Vec::new();
        let mut ctx = out.clone();
        for _ in 0..k {
            let q = synth_dist(qsalt, &ctx, vocab, 2.0);
            let x = match mode {
                SamplingMode::Stochastic => {
                    categorical_from_uniform(&q, rng.uniform() as f32) as i32
                }
                _ => argmax(&q) as i32,
            };
            q_rows.extend_from_slice(&q);
            drafts.push(x);
            ctx.push(x);
        }
        let mut p_rows: Vec<f32> = Vec::new();
        let mut ctx = out.clone();
        for j in 0..=k {
            p_rows.extend_from_slice(&synth_dist(psalt, &ctx, vocab, 2.0));
            if j < k {
                ctx.push(drafts[j]);
            }
        }
        let u = RoundUniforms::draw(rng, k, mode);
        let rv = verify_round(k, vocab, &p_rows, &q_rows, &drafts, mode, &u);
        observe(k, rv.n_accepted);
        out.extend_from_slice(&drafts[..rv.n_accepted]);
        out.push(rv.token);
        rounds += 1;
    }
    out.truncate(len);
    (out, rounds)
}

/// THE adaptive exactness theorem (greedy modes): the emitted sequence
/// is the target's greedy path position by position, so ANY per-round-K
/// schedule — every fixed K, arbitrary random schedules, and a live
/// `SpecController` — emits bit-identical tokens. Only round counts
/// change (pinned via the all-accepting q == p case, where K=7 rounds
/// emit 8 tokens and K=1 rounds emit 2).
#[test]
fn prop_adaptive_k_schedule_greedy_exact() {
    use lk_spec::spec::adaptive::{ControllerCfg, SpecController};
    forall(
        "greedy emission is k-schedule invariant",
        0xADA9,
        16,
        |rng| {
            let psalt = rng.next_u64();
            // Half the cases draft from the target itself (clean sweeps:
            // round counts collapse at large K); half from an unrelated
            // model (constant rejections).
            let qsalt = if rng.below(2) == 0 { psalt } else { rng.next_u64() };
            (psalt, qsalt, rng.next_u64())
        },
        |&(psalt, qsalt, seed)| {
            let (vocab, len) = (12usize, 40usize);
            // Reference: the pure greedy rollout of the target model.
            let mut reference: Vec<i32> = Vec::new();
            for _ in 0..len {
                let p = synth_dist(psalt, &reference, vocab, 2.0);
                reference.push(lk_spec::spec::sampling::argmax(&p) as i32);
            }
            let mut rounds_seen = Vec::new();
            // Every fixed K…
            for k in 1..=7usize {
                let mut rng = Pcg64::new(seed, k as u64);
                let (toks, rounds) = decode_schedule(
                    psalt, qsalt, vocab, len,
                    SamplingMode::Greedy, &mut rng, |_| k, |_, _| {},
                );
                if toks != reference {
                    return Err(format!("fixed k={k} diverged from greedy path"));
                }
                rounds_seen.push(rounds);
            }
            // …a random schedule…
            let mut sched_rng = Pcg64::new(seed, 99);
            let mut rng = Pcg64::new(seed, 100);
            let (toks, _) = decode_schedule(
                psalt, qsalt, vocab, len,
                SamplingMode::Greedy, &mut rng,
                |_| 1 + sched_rng.below(7), |_, _| {},
            );
            if toks != reference {
                return Err("random schedule diverged from greedy path".into());
            }
            // …and the live controller, observing its own rounds.
            let ctrl = std::cell::RefCell::new(SpecController::new(ControllerCfg {
                warmup: 0,
                ..Default::default()
            }));
            let mut rng = Pcg64::new(seed, 101);
            let (toks, ctrl_rounds) = decode_schedule(
                psalt, qsalt, vocab, len,
                SamplingMode::Greedy, &mut rng,
                |_| ctrl.borrow_mut().choose_k(),
                |k, n| ctrl.borrow_mut().observe_chain(k, n),
            );
            if toks != reference {
                return Err("controller schedule diverged from greedy path".into());
            }
            // Round counts are where schedules differ: with q == p every
            // draft accepts, so K=7 needs ~len/8 rounds and K=1 ~len/2.
            if qsalt == psalt && rounds_seen[0] <= rounds_seen[6] {
                return Err(format!(
                    "all-accept case: k=1 rounds {} not above k=7 rounds {}",
                    rounds_seen[0], rounds_seen[6]
                ));
            }
            let _ = ctrl_rounds;
            Ok(())
        },
    );
}

/// Stochastic mode under ANY k-schedule stays exactly lossless: the
/// joint law of the first two emitted tokens equals the target's
/// autoregressive 2-gram p(a)·p(b|a), with a fresh random schedule per
/// trial (round boundaries land differently every time).
#[test]
fn prop_adaptive_k_schedule_stochastic_lossless() {
    forall(
        "any k-schedule preserves the 2-gram law",
        0xADA5,
        3,
        |rng| (rng.next_u64(), rng.next_u64(), rng.next_u64()),
        |&(psalt, qsalt, seed)| {
            let vocab = 8usize;
            let n = 120_000usize;
            let mut rng = Pcg64::new(seed, 7);
            let mut joint = vec![0f64; vocab * vocab];
            for _ in 0..n {
                let mut sched_rng = rng.fork(11);
                let (toks, _) = decode_schedule(
                    psalt, qsalt, vocab, 2,
                    SamplingMode::Stochastic, &mut rng,
                    |_| 1 + sched_rng.below(4), |_, _| {},
                );
                joint[toks[0] as usize * vocab + toks[1] as usize] += 1.0;
            }
            let p0 = synth_dist(psalt, &[], vocab, 2.0);
            for a in 0..vocab {
                let p1 = synth_dist(psalt, &[a as i32], vocab, 2.0);
                for b in 0..vocab {
                    let want = p0[a] as f64 * p1[b] as f64;
                    let emp = joint[a * vocab + b] / n as f64;
                    let tol = 0.012 + 3.0 * (want / n as f64).sqrt();
                    if (emp - want).abs() > tol {
                        return Err(format!(
                            "2-gram ({a},{b}): |{emp:.4} - {want:.4}| > {tol:.4}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// One decode round of RECURRENT tree drafting over the synthetic
/// prefix-deterministic model — the engine-shaped mirror of
/// `RecurrentTree::propose_tree` + the tree verify round. Unlike the
/// parallel-head (medusa) construction, node `i`'s draft distribution
/// conditions on its ANCESTOR candidates (the EAGLE recurrence made
/// path-dependent): q_i = q(· | prefix, path-to-parent(i)). Uniform
/// order follows the fixed-uniform contract exactly: one draft draw per
/// node in node order (stochastic), then one accept draw per node plus
/// the single sample draw.
fn decode_recurrent_tree(
    psalt: u64,
    qsalt: u64,
    vocab: usize,
    len: usize,
    mode: SamplingMode,
    rng: &mut Pcg64,
    tree: &TreeSpec,
) -> (Vec<i32>, usize) {
    let n = tree.len();
    let mut out: Vec<i32> = Vec::new();
    let mut rounds = 0usize;
    let mut scratch = Vec::new();
    let path_ctx = |tree: &TreeSpec, drafts: &[i32], node: i32, out: &[i32]| {
        // prefix + the candidate tokens along node's root path
        let mut chain = Vec::new();
        let mut p = node;
        while p >= 0 {
            chain.push(drafts[p as usize]);
            p = tree.parent(p as usize);
        }
        chain.reverse();
        let mut ctx = out.to_vec();
        ctx.extend(chain);
        ctx
    };
    while out.len() < len {
        let mut drafts = vec![0i32; n];
        let mut q_rows: Vec<f32> = Vec::new();
        for i in 0..n {
            let ctx = path_ctx(tree, &drafts, tree.parent(i), &out);
            let q = synth_dist(qsalt, &ctx, vocab, 2.0);
            drafts[i] = match mode {
                SamplingMode::Stochastic => {
                    categorical_from_uniform(&q, rng.uniform() as f32) as i32
                }
                _ => argmax_rank(&q, tree.rank(i), &mut scratch) as i32,
            };
            q_rows.extend(q);
        }
        // target rows per block slot: root, then one row past each node
        let mut p_rows: Vec<f32> = synth_dist(psalt, &out, vocab, 2.0);
        for i in 0..n {
            let ctx = path_ctx(tree, &drafts, i as i32, &out);
            p_rows.extend(synth_dist(psalt, &ctx, vocab, 2.0));
        }
        let u = RoundUniforms::draw(rng, n, mode);
        let tv = verify_tree(tree, vocab, &p_rows, &q_rows, &drafts, mode, &u);
        for &node in &tv.path {
            out.push(drafts[node]);
        }
        out.push(tv.token);
        rounds += 1;
    }
    out.truncate(len);
    (out, rounds)
}

/// THE recurrent-tree chain-degeneracy property (the ISSUE-5 acceptance
/// criterion, mirroring PR-3's medusa-tree property): a degenerate
/// single-chain topology through the recurrent tree round reproduces
/// the chain backend's decode EXACTLY — bit-identical token sequences
/// in the greedy modes AND under golden stochastic uniforms (same
/// stream draws, same verdicts, same emissions), with identical round
/// counts. This pins the whole construction: path-dependent candidate
/// sampling in node order, the per-node q layout, the block row
/// convention and the verify walk all collapse to the chain round.
#[test]
fn prop_recurrent_tree_chain_degenerates_to_chain_decode() {
    forall(
        "recurrent chain-tree == chain decode",
        0xEA91,
        12,
        |rng| {
            let k = 1 + rng.below(6);
            let psalt = rng.next_u64();
            // half the cases draft from the target itself (clean sweeps)
            let qsalt = if rng.below(2) == 0 { psalt } else { rng.next_u64() };
            let mode = [
                SamplingMode::Stochastic,
                SamplingMode::Greedy,
                SamplingMode::GreedyDraft,
            ][rng.below(3)];
            (k, psalt, qsalt, rng.next_u64(), mode)
        },
        |&(k, psalt, qsalt, seed, mode)| {
            let (vocab, len) = (12usize, 36usize);
            let mut rng_chain = Pcg64::new(seed, 1);
            let (chain_toks, chain_rounds) = decode_schedule(
                psalt, qsalt, vocab, len, mode, &mut rng_chain, |_| k, |_, _| {},
            );
            let mut rng_tree = Pcg64::new(seed, 1);
            let (tree_toks, tree_rounds) = decode_recurrent_tree(
                psalt, qsalt, vocab, len, mode, &mut rng_tree,
                &TreeSpec::chain(k),
            );
            if tree_toks != chain_toks {
                return Err(format!(
                    "{mode:?} k={k}: chain-topology tree decode diverged \
                     from the chain backend"
                ));
            }
            if tree_rounds != chain_rounds {
                return Err(format!(
                    "{mode:?} k={k}: round counts differ ({tree_rounds} vs \
                     {chain_rounds})"
                ));
            }
            // and the streams stayed aligned (same per-round draw count)
            if rng_chain.next_u64() != rng_tree.next_u64() {
                return Err("RNG streams misaligned after identical rounds".into());
            }
            Ok(())
        },
    );
}

/// Structural sanity of the recurrent tree on BRANCHING topologies: the
/// decode emits a valid sequence, rounds advance, and in greedy mode
/// the emission is the target's greedy path position by position (any
/// topology — breadth only changes round counts, never tokens).
#[test]
fn prop_recurrent_tree_greedy_is_topology_invariant() {
    forall(
        "recurrent tree greedy == greedy path",
        0xEA92,
        10,
        |rng| {
            let fanout: Vec<usize> =
                (0..1 + rng.below(2)).map(|_| 1 + rng.below(2)).collect();
            (
                TreeSpec::from_fanout(&fanout).unwrap(),
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64(),
            )
        },
        |(tree, psalt, qsalt, seed)| {
            let (vocab, len) = (10usize, 24usize);
            let mut reference: Vec<i32> = Vec::new();
            for _ in 0..len {
                let p = synth_dist(*psalt, &reference, vocab, 2.0);
                reference.push(lk_spec::spec::sampling::argmax(&p) as i32);
            }
            let mut rng = Pcg64::new(*seed, 2);
            let (toks, rounds) = decode_recurrent_tree(
                *psalt, *qsalt, vocab, len, SamplingMode::Greedy, &mut rng, tree,
            );
            if toks != reference {
                return Err(format!(
                    "fanout tree {:?} diverged from the greedy path",
                    (0..tree.len()).map(|i| tree.parent(i)).collect::<Vec<_>>()
                ));
            }
            if rounds == 0 || rounds > len {
                return Err(format!("implausible round count {rounds}"));
            }
            Ok(())
        },
    );
}

/// Replay determinism of adaptive runs: a schedule consumes exactly
/// k draft + k accept + 1 sample draws per round, so (seed, schedule)
/// fully determines the stochastic sample path — equal schedules are
/// bit-identical however they are produced, and a constant schedule IS
/// the fixed-K engine. (Distinct schedules are distinct couplings of
/// the same law — see DESIGN.md §4a for why cross-schedule bit-equality
/// is impossible in stochastic mode.)
#[test]
fn prop_adaptive_constant_schedule_is_fixed_k() {
    forall(
        "constant schedule == fixed K, bit for bit",
        0xADAC,
        12,
        |rng| (rng.next_u64(), rng.next_u64(), rng.next_u64(), 1 + rng.below(7)),
        |&(psalt, qsalt, seed, k)| {
            let (vocab, len) = (10usize, 30usize);
            let mut rng_a = Pcg64::new(seed, 1);
            let (fixed, rounds_a) = decode_schedule(
                psalt, qsalt, vocab, len,
                SamplingMode::Stochastic, &mut rng_a, |_| k, |_, _| {},
            );
            // The same k produced by a stateful "controller" closure.
            let mut calls = 0usize;
            let mut rng_b = Pcg64::new(seed, 1);
            let (ctrl, rounds_b) = decode_schedule(
                psalt, qsalt, vocab, len,
                SamplingMode::Stochastic, &mut rng_b,
                |_| {
                    calls += 1;
                    k
                },
                |_, _| {},
            );
            if fixed != ctrl {
                return Err("constant schedule diverged from fixed K".into());
            }
            if rounds_a != rounds_b || calls != rounds_b {
                return Err("round accounting diverged".into());
            }
            // And the streams stayed aligned: both RNGs sit at the same
            // position after identical per-round draw counts.
            if rng_a.next_u64() != rng_b.next_u64() {
                return Err("RNG streams misaligned after equal schedules".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// HTTP request parser: torn-read invariance (server/http/parse.rs)
// ---------------------------------------------------------------------------

/// Feed a parser the given byte pieces in order; stop at the first
/// completed request or sticky error — exactly what a connection
/// handler's read loop does.
fn run_http_parser(pieces: &[&[u8]]) -> Result<Option<HttpRequest>, ParseError> {
    let mut p = RequestParser::new(ParseLimits::default());
    for piece in pieces {
        match p.feed(piece) {
            Ok(None) => {}
            done => return done,
        }
    }
    Ok(None)
}

/// TCP may tear a request anywhere: whole-buffer, byte-at-a-time, and
/// random-split framings of the same byte stream must produce the
/// IDENTICAL parse — same request, or same typed error — for
/// well-formed and malformed corpus entries alike.
#[test]
fn prop_http_parser_split_invariant() {
    let mut oversized_head = b"GET / HTTP/1.1\r\nX-Pad: ".to_vec();
    oversized_head.resize(oversized_head.len() + 9000, b'a');
    oversized_head.extend_from_slice(b"\r\n\r\n");
    let corpus: Vec<Vec<u8>> = vec![
        b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),
        b"GET /metrics HTTP/1.1\r\nAccept: text/plain\r\nX-Trace: abc\r\n\r\n".to_vec(),
        b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 34\r\n\r\n\
          {\"prompt\": [1, 2], \"max_new\": 8}\r\n"
            .to_vec(),
        // Malformed: wrong version, bare LF, smuggling shapes -> 400.
        b"GET / HTTP/1.0\r\nHost: x\r\n\r\n".to_vec(),
        b"GET / HTTP/1.1\nHost: x\r\n\r\n".to_vec(),
        b"GET / HTTP/1.1\r\nHost : x\r\n\r\n".to_vec(),
        b"junk\r\n\r\n".to_vec(),
        // Oversized: declared body -> 413, giant head -> 431.
        b"POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n".to_vec(),
        oversized_head,
    ];
    forall(
        "http parser split invariance",
        0x7C9E11,
        64,
        |rng| {
            let raw = corpus[rng.below(corpus.len())].clone();
            let mut cuts: Vec<usize> = (0..1 + rng.below(6)).map(|_| rng.below(raw.len())).collect();
            cuts.sort_unstable();
            (raw, cuts)
        },
        |(raw, cuts)| {
            let whole = run_http_parser(&[&raw[..]]);
            let bytes: Vec<&[u8]> = raw.chunks(1).collect();
            if run_http_parser(&bytes) != whole {
                return Err(format!("byte-at-a-time diverged from whole (want {whole:?})"));
            }
            let mut pieces = Vec::new();
            let mut prev = 0usize;
            for &c in cuts {
                pieces.push(&raw[prev..c]);
                prev = c;
            }
            pieces.push(&raw[prev..]);
            if run_http_parser(&pieces) != whole {
                return Err(format!("split at {cuts:?} diverged from whole (want {whole:?})"));
            }
            Ok(())
        },
    );
}

/// Garbage in, typed verdict out: random binary noise, CRLF-sprinkled
/// ASCII, and corrupted valid prefixes must never panic the parser —
/// every failure is a 400/413/431 verdict, and verdicts are sticky.
#[test]
fn prop_http_parser_never_panics_on_garbage() {
    forall(
        "http parser survives garbage",
        0xBADB17E5,
        128,
        |rng| {
            let len = 1 + rng.below(600);
            let mode = rng.below(3);
            let mut raw = Vec::with_capacity(len + 32);
            if mode == 2 {
                raw.extend_from_slice(b"POST /v1/generate HTTP/1.1\r\n");
            }
            for _ in 0..len {
                let b = match (mode, rng.below(8)) {
                    (0, _) => rng.below(256) as u8,
                    (_, 0) => b'\r',
                    (_, 1) => b'\n',
                    (_, 2) => b' ',
                    (_, 3) => b':',
                    _ => b'a' + rng.below(26) as u8,
                };
                raw.push(b);
            }
            raw.extend_from_slice(b"\r\n\r\n");
            raw
        },
        |raw| {
            let mut p = RequestParser::new(ParseLimits::default());
            match p.feed(raw) {
                Ok(_) => Ok(()), // parsed or still waiting — both fine
                Err(e) => {
                    let status = e.http_status();
                    if !matches!(status, 400 | 413 | 431) {
                        return Err(format!("unmapped status {status} for {e:?}"));
                    }
                    // Sticky: the poisoned parser keeps refusing with
                    // the same verdict.
                    match p.feed(b"GET /healthz HTTP/1.1\r\n\r\n") {
                        Err(e2) if e2 == e => Ok(()),
                        other => Err(format!("error not sticky: {other:?} after {e:?}")),
                    }
                }
            }
        },
    );
}
