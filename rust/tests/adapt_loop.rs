//! Online-adaptation suite (DESIGN.md §12), PJRT-free.
//!
//! Two layers of swap-safety evidence:
//!
//!  1. Sampling-level properties over the audited `verify_round`: a
//!     draft hot-swap only changes WHAT is proposed, never the
//!     accept/resample rule, so greedy decode stays the target's argmax
//!     path and stochastic decode stays distribution-lossless across
//!     arbitrary swap round boundaries — for all three chain-drafting
//!     constructions (recurrent EAGLE/MTP-shaped, parallel-head
//!     MEDUSA-shaped, single-step MLP-shaped) × all three sampling
//!     modes.
//!  2. Scheduler-level properties over `SimCore` + the REAL
//!     `AdaptDriver`: harvest → background fine-tune → hot-swap at
//!     round boundaries leaves every session's served tokens
//!     bit-identical to a no-adaptation run, and every trainer fault
//!     (crash / hang / malformed protocol / bad checkpoint) is a typed
//!     TRANSIENT fault that keeps the stale weights serving.

use std::path::PathBuf;
use std::time::Instant;

use lk_spec::server::batcher::BatcherConfig;
use lk_spec::server::{
    AdaptConfig, FaultKind, FaultPlan, RequestResult, Scheduler, SimCore, TrainerFault,
    TrainerSpec,
};
use lk_spec::spec::sampling::{
    argmax, categorical_from_uniform, verify_round, RoundUniforms, SamplingMode,
};
use lk_spec::util::proptest::{forall, gen};
use lk_spec::util::Pcg64;

// ---------------------------------------------------------------------------
// sampling-level swap safety (exactness across swap boundaries)
// ---------------------------------------------------------------------------

/// Prefix-deterministic synthetic model (the properties.rs substrate):
/// the distribution at a position is a pure function of (salt, prefix).
fn synth_dist(salt: u64, prefix: &[i32], vocab: usize, sharp: f64) -> Vec<f32> {
    let mut h = salt;
    for &t in prefix {
        h = h
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(t as u64 + 1);
    }
    let mut rng = Pcg64::new(h, 0x5EED);
    gen::dist(&mut rng, vocab, sharp)
}

/// The three chain-backend conditioning shapes (`server::backend`):
/// how draft slot `i`'s distribution conditions on context.
#[derive(Clone, Copy, Debug, PartialEq)]
enum DraftShape {
    /// EAGLE-3/MTP: slot i sees prefix + all speculated drafts before it.
    Recurrent,
    /// MEDUSA: head i sees only the committed prefix (per-head salt).
    ParallelHead,
    /// MLP: slot i sees a one-token window (the immediately previous
    /// token only).
    SingleStep,
}

const SHAPES: [DraftShape; 3] = [
    DraftShape::Recurrent,
    DraftShape::ParallelHead,
    DraftShape::SingleStep,
];

fn draft_dist(
    shape: DraftShape,
    qsalt: u64,
    out: &[i32],
    drafts: &[i32],
    slot: usize,
    vocab: usize,
) -> Vec<f32> {
    match shape {
        DraftShape::Recurrent => {
            let mut ctx = out.to_vec();
            ctx.extend_from_slice(&drafts[..slot]);
            synth_dist(qsalt, &ctx, vocab, 2.0)
        }
        DraftShape::ParallelHead => {
            let head_salt = qsalt ^ (slot as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            synth_dist(head_salt, out, vocab, 2.0)
        }
        DraftShape::SingleStep => {
            let last = if slot > 0 {
                drafts.get(slot - 1).copied()
            } else {
                out.last().copied()
            };
            let window: Vec<i32> = last.into_iter().collect();
            synth_dist(qsalt, &window, vocab, 2.0)
        }
    }
}

/// Decode `len` tokens through engine-shaped k-chains where the DRAFT
/// MODEL is a per-round function (`qsalt_of(round)`) — the sampling-
/// level shape of a hot-swap: weights change only at round boundaries,
/// the verify rule never changes. Uniform order follows the fixed-
/// uniform contract: per round, one draft draw per slot (stochastic
/// mode only), then k accept draws + one sample draw (stochastic
/// modes). The target model (`psalt`) conditions on the speculated
/// prefix as the engine's verify pass does.
fn decode_with_swaps(
    shape: DraftShape,
    psalt: u64,
    mut qsalt_of: impl FnMut(usize) -> u64,
    vocab: usize,
    len: usize,
    k: usize,
    mode: SamplingMode,
    rng: &mut Pcg64,
) -> (Vec<i32>, usize) {
    let mut out: Vec<i32> = Vec::new();
    let mut rounds = 0usize;
    while out.len() < len {
        let qsalt = qsalt_of(rounds);
        let mut drafts: Vec<i32> = Vec::with_capacity(k);
        let mut q_rows: Vec<f32> = Vec::new();
        for i in 0..k {
            let q = draft_dist(shape, qsalt, &out, &drafts, i, vocab);
            let x = match mode {
                SamplingMode::Stochastic => {
                    categorical_from_uniform(&q, rng.uniform() as f32) as i32
                }
                _ => argmax(&q) as i32,
            };
            q_rows.extend_from_slice(&q);
            drafts.push(x);
        }
        let mut p_rows: Vec<f32> = Vec::new();
        let mut ctx = out.clone();
        for j in 0..=k {
            p_rows.extend_from_slice(&synth_dist(psalt, &ctx, vocab, 2.0));
            if j < k {
                ctx.push(drafts[j]);
            }
        }
        let u = RoundUniforms::draw(rng, k, mode);
        let rv = verify_round(k, vocab, &p_rows, &q_rows, &drafts, mode, &u);
        out.extend_from_slice(&drafts[..rv.n_accepted]);
        out.push(rv.token);
        rounds += 1;
    }
    out.truncate(len);
    (out, rounds)
}

/// A random swap schedule: toggle between two drafters at 1–3 random
/// round boundaries (deterministic in `seed`).
fn toggle_schedule(seed: u64, qa: u64, qb: u64) -> impl FnMut(usize) -> u64 {
    let mut rng = Pcg64::new(seed, 0x5A9);
    let n = 1 + rng.below(3);
    let mut cuts: Vec<usize> = (0..n).map(|_| rng.below(20)).collect();
    cuts.sort_unstable();
    move |round| {
        let flips = cuts.iter().filter(|&&c| c <= round).count();
        if flips % 2 == 0 {
            qa
        } else {
            qb
        }
    }
}

/// GREEDY swap safety: the emitted sequence is the target's greedy path
/// position by position, so swapping the drafter at ARBITRARY round
/// boundaries — any of the three chain-backend conditioning shapes —
/// leaves the output bit-identical to the vanilla target decode.
#[test]
fn prop_greedy_decode_is_swap_invariant() {
    forall(
        "greedy emission invariant under draft hot-swaps",
        0x5AFE,
        16,
        |rng| {
            let k = 1 + rng.below(6);
            (rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64(), k)
        },
        |&(psalt, qa, qb, seed, k)| {
            let (vocab, len) = (12usize, 40usize);
            let mut reference: Vec<i32> = Vec::new();
            for _ in 0..len {
                let p = synth_dist(psalt, &reference, vocab, 2.0);
                reference.push(argmax(&p) as i32);
            }
            for shape in SHAPES {
                let mut rng = Pcg64::new(seed, 1);
                let (toks, rounds) = decode_with_swaps(
                    shape,
                    psalt,
                    toggle_schedule(seed, qa, qb),
                    vocab,
                    len,
                    k,
                    SamplingMode::Greedy,
                    &mut rng,
                );
                if toks != reference {
                    return Err(format!(
                        "{shape:?} k={k}: swap schedule diverged from the greedy path"
                    ));
                }
                if rounds == 0 || rounds > len {
                    return Err(format!("{shape:?}: implausible round count {rounds}"));
                }
            }
            Ok(())
        },
    );
}

/// STOCHASTIC swap safety: the emission law stays EXACTLY the target
/// law under arbitrary swap schedules — the joint law of the first two
/// tokens equals the autoregressive 2-gram p(a)·p(b|a), with a fresh
/// random swap boundary (and drafter pair) per trial, for each
/// conditioning shape. The Leviathan rule is per-round, so losslessness
/// cannot depend on WHICH drafter proposed, only that verify uses the
/// matching q — which is what a round-boundary swap preserves.
#[test]
fn prop_stochastic_decode_stays_lossless_across_swaps() {
    forall(
        "any swap schedule preserves the 2-gram law",
        0x5AFF,
        3,
        |rng| {
            let shape = SHAPES[rng.below(3)];
            (rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64(), shape)
        },
        |&(psalt, qa, qb, seed, shape)| {
            let vocab = 8usize;
            let n = 40_000usize;
            let mut rng = Pcg64::new(seed, 7);
            let mut joint = vec![0f64; vocab * vocab];
            for t in 0..n {
                let (toks, _) = decode_with_swaps(
                    shape,
                    psalt,
                    toggle_schedule(seed ^ t as u64, qa, qb),
                    vocab,
                    2,
                    1 + (t % 3),
                    SamplingMode::Stochastic,
                    &mut rng,
                );
                joint[toks[0] as usize * vocab + toks[1] as usize] += 1.0;
            }
            let p0 = synth_dist(psalt, &[], vocab, 2.0);
            for a in 0..vocab {
                let p1 = synth_dist(psalt, &[a as i32], vocab, 2.0);
                for b in 0..vocab {
                    let want = p0[a] as f64 * p1[b] as f64;
                    let emp = joint[a * vocab + b] / n as f64;
                    let tol = 0.018 + 3.0 * (want / n as f64).sqrt();
                    if (emp - want).abs() > tol {
                        return Err(format!(
                            "{shape:?} 2-gram ({a},{b}): |{emp:.4} - {want:.4}| > {tol:.4}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// GREEDY-DRAFT (the Appendix D ablation mode) swap safety: the mode is
/// deliberately lossy, so the exactness claims above don't apply — the
/// invariant that MUST survive a swap is determinism under the
/// fixed-uniform contract: the decode is a pure function of
/// (seed, swap schedule), so an identical replay is bit-identical with
/// aligned RNG streams, for every conditioning shape.
#[test]
fn prop_greedy_draft_swap_replay_is_deterministic() {
    forall(
        "greedy-draft decode replays bit-identically under swaps",
        0x5B00,
        24,
        |rng| {
            let k = 1 + rng.below(6);
            let shape = SHAPES[rng.below(3)];
            (rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64(), k, shape)
        },
        |&(psalt, qa, qb, seed, k, shape)| {
            let (vocab, len) = (10usize, 30usize);
            let mut rng_a = Pcg64::new(seed, 3);
            let (ta, ra) = decode_with_swaps(
                shape, psalt, toggle_schedule(seed, qa, qb),
                vocab, len, k, SamplingMode::GreedyDraft, &mut rng_a,
            );
            let mut rng_b = Pcg64::new(seed, 3);
            let (tb, rb) = decode_with_swaps(
                shape, psalt, toggle_schedule(seed, qa, qb),
                vocab, len, k, SamplingMode::GreedyDraft, &mut rng_b,
            );
            if ta != tb || ra != rb {
                return Err(format!("{shape:?} k={k}: replay diverged"));
            }
            if rng_a.next_u64() != rng_b.next_u64() {
                return Err("RNG streams misaligned after identical replays".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// scheduler-level swap safety + trainer chaos (SimCore + real AdaptDriver)
// ---------------------------------------------------------------------------

fn cfg(queue_cap: usize) -> BatcherConfig {
    BatcherConfig {
        buckets: vec![1, 4],
        max_wait: std::time::Duration::ZERO,
        queue_cap,
    }
}

/// A low-acceptance starting drafter: plenty of rejections to harvest,
/// plenty of headroom for the fine-tune to close.
fn shifted_sim(seed: u64) -> SimCore {
    SimCore::new(4, seed, vec![1, 4]).with_alpha(vec![vec![0.35, 0.3, 0.25, 0.2]])
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lk_adapt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn adapt_cfg(tag: &str, interval: u64) -> AdaptConfig {
    AdaptConfig {
        interval_rounds: interval,
        min_records: 8,
        trainer: TrainerSpec::BuiltinSim,
        out_dir: tmp_dir(tag),
        ..AdaptConfig::default()
    }
}

/// Submit a fixed workload and tick to completion, collecting tokens.
fn run_workload(s: &mut Scheduler<SimCore>) -> Vec<(u64, RequestResult)> {
    for i in 0..6i32 {
        s.submit(vec![i + 1, 2 * i + 7, 3], 40 + 4 * i as usize).unwrap();
    }
    let mut out = Vec::new();
    let mut ticks = 0;
    while !s.is_idle() {
        out.extend(s.tick(Instant::now()).unwrap());
        ticks += 1;
        assert!(ticks < 20_000, "scheduler did not converge");
    }
    out.sort_by_key(|(id, _)| *id);
    out
}

fn tokens_of(results: &[(u64, RequestResult)]) -> Vec<(u64, Vec<i32>)> {
    results
        .iter()
        .map(|(id, r)| (*id, r.tokens.clone()))
        .collect()
}

/// THE scheduler-level swap-safety property: with the REAL adaptation
/// loop running (harvest → BuiltinSim fine-tune → hot-swap through
/// `SchedulerCore::swap_draft`, swaps landing at driver-chosen round
/// boundaries that vary with the interval), every session's served
/// tokens are BIT-IDENTICAL to a run with no adaptation at all. The
/// drafter only shapes acceptance (rounds), never emissions.
#[test]
fn prop_hot_swaps_never_change_served_tokens() {
    forall(
        "served tokens invariant under live hot-swaps",
        0xADA7,
        6,
        |rng| (rng.next_u64(), 2 + rng.below(5) as u64),
        |&(seed, interval)| {
            let mut base = Scheduler::new(shifted_sim(seed), cfg(64));
            let base_toks = tokens_of(&run_workload(&mut base));

            let tag = format!("swap_{seed:x}_{interval}");
            let mut s = Scheduler::new(shifted_sim(seed), cfg(64))
                .with_adaptation(adapt_cfg(&tag, interval));
            let adapt_toks = tokens_of(&run_workload(&mut s));
            let driver = s.adapt().expect("driver attached");
            if driver.metrics.swaps_total == 0 {
                return Err(format!(
                    "no hot-swap fired (interval {interval}) — property vacuous"
                ));
            }
            if driver.metrics.records_harvested_total == 0 {
                return Err("no records harvested".into());
            }
            if adapt_toks != base_toks {
                return Err(format!(
                    "served tokens changed across {} hot-swap(s)",
                    driver.metrics.swaps_total
                ));
            }
            Ok(())
        },
    );
}

/// The adaptation-drift claim at test scale: fine-tuning on the live
/// transcript strictly improves the harvested acceptance rate once the
/// swapped drafter starts serving (the bench pins the same claim on the
/// domain-shifted corpus mix).
#[test]
fn fine_tune_improves_harvested_alpha() {
    let mut s =
        Scheduler::new(shifted_sim(0xD01F), cfg(64)).with_adaptation(adapt_cfg("drift", 3));
    let _ = run_workload(&mut s);
    let m = &s.adapt().unwrap().metrics;
    assert!(m.swaps_total >= 1, "no swap committed");
    assert!(
        m.alpha_hat_pre > 0.0 && m.alpha_hat_pre < 1.0,
        "pre-swap alpha_hat {:.3} not a proper rate",
        m.alpha_hat_pre
    );
    assert!(
        m.alpha_hat_post > m.alpha_hat_pre,
        "alpha_hat did not improve: {:.3} -> {:.3}",
        m.alpha_hat_pre,
        m.alpha_hat_post
    );
}

/// The adapt gauges render under the `lkspec_adapt_` namespace.
#[test]
fn adapt_metrics_render() {
    let mut s =
        Scheduler::new(shifted_sim(0x3E7), cfg(64)).with_adaptation(adapt_cfg("metrics", 4));
    let _ = run_workload(&mut s);
    let text = s.adapt().unwrap().metrics.render("sim");
    for gauge in [
        "lkspec_adapt_buffer_depth",
        "lkspec_adapt_records_harvested_total",
        "lkspec_adapt_trainer_runs_total",
        "lkspec_adapt_swaps_total",
        "lkspec_adapt_alpha_hat_post",
    ] {
        assert!(text.contains(gauge), "missing gauge {gauge} in:\n{text}");
    }
    assert!(text.contains("engine=\"sim\""));
}

/// Run the workload under a trainer-chaos plan; return the served
/// tokens and the driver's (faults, metrics) evidence.
fn run_with_chaos(
    tag: &str,
    plan: FaultPlan,
    seed: u64,
) -> (Vec<(u64, Vec<i32>)>, Vec<TrainerFault>, u64, u64) {
    let acfg = adapt_cfg(tag, 3).with_chaos(plan.trainer.clone());
    let mut s = Scheduler::new(
        shifted_sim(seed).with_fault_plan(plan),
        cfg(64),
    )
    .with_adaptation(acfg);
    let toks = tokens_of(&run_workload(&mut s));
    // The faulty subprocess may still be mid-flight when serving ends:
    // keep ticking the idle scheduler (each tick polls the trainer)
    // until the launch resolves one way or the other.
    let mut spins = 0;
    while s.adapt().unwrap().trainer_running() {
        let _ = s.tick(Instant::now()).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        spins += 1;
        assert!(spins < 2_000, "trainer launch never resolved");
    }
    let driver = s.adapt().unwrap();
    (
        toks,
        driver.faults.clone(),
        driver.metrics.trainer_faults_total,
        driver.metrics.swaps_total,
    )
}

/// Trainer chaos matrix: a mid-fine-tune crash / hang / malformed
/// event stream each maps to its TYPED TrainerFault, every one of them
/// classifies TRANSIENT (advisory loop — never session- or
/// engine-fatal), serving stays bit-identical to the unfaulted
/// no-trainer run, and the stale drafter keeps serving (a later clean
/// run may still swap).
#[test]
fn trainer_chaos_faults_are_typed_transient_and_contained() {
    let seed = 0xC4A05u64;
    let mut base = Scheduler::new(shifted_sim(seed), cfg(64));
    let base_toks = tokens_of(&run_workload(&mut base));

    let cases: [(&str, FaultPlan, fn(&TrainerFault) -> bool); 3] = [
        ("kill", FaultPlan::default().trainer_kill_at(0), |f| {
            matches!(f, TrainerFault::Crashed { .. })
        }),
        ("hang", FaultPlan::default().trainer_hang_at(0), |f| {
            matches!(f, TrainerFault::Hang { .. })
        }),
        ("malformed", FaultPlan::default().trainer_malformed_at(0), |f| {
            matches!(f, TrainerFault::Protocol { .. })
        }),
    ];
    for (tag, plan, is_expected) in cases {
        let (toks, faults, faults_total, _swaps) = run_with_chaos(tag, plan, seed);
        assert_eq!(
            toks, base_toks,
            "{tag}: trainer fault leaked into served tokens"
        );
        assert!(
            faults_total >= 1,
            "{tag}: fault not counted (faults: {faults:?})"
        );
        let fault = faults
            .iter()
            .find(|f| is_expected(f))
            .unwrap_or_else(|| panic!("{tag}: expected fault variant missing in {faults:?}"));
        assert_eq!(
            fault.kind(),
            FaultKind::Transient,
            "{tag}: trainer fault must be transient"
        );
    }
}

/// After a faulted run, the NEXT clean launch still fine-tunes and
/// swaps: a trainer fault costs one epoch, not the loop.
#[test]
fn trainer_fault_then_recovery_swaps() {
    let (_, faults, faults_total, swaps) = run_with_chaos(
        "recover",
        FaultPlan::default().trainer_kill_at(0),
        0xC4A06,
    );
    assert!(faults_total >= 1, "chaos run recorded no fault");
    assert!(
        faults.iter().any(|f| matches!(f, TrainerFault::Crashed { .. })),
        "missing crash fault: {faults:?}"
    );
    assert!(
        swaps >= 1,
        "clean follow-up run never swapped (swaps = {swaps})"
    );
}

/// A trainer that completes but hands back an unloadable checkpoint:
/// validate-then-commit ROLLS BACK (swap_rollbacks counted, no swap
/// committed) and the stale drafter keeps serving bit-identically.
#[test]
fn bad_checkpoint_rolls_back_and_keeps_serving() {
    let seed = 0xBADC4u64;
    let mut base = Scheduler::new(shifted_sim(seed), cfg(64));
    let base_toks = tokens_of(&run_workload(&mut base));

    let mut acfg = adapt_cfg("rollback", 3);
    acfg.trainer = TrainerSpec::Command(vec![
        "sh".into(),
        "-c".into(),
        r#"printf '%s\n' '{"kind":"done","payload":{"checkpoint":"/nonexistent/ckpt.json","epoch":1}}'"#
            .into(),
    ]);
    let mut s = Scheduler::new(shifted_sim(seed), cfg(64)).with_adaptation(acfg);
    let toks = tokens_of(&run_workload(&mut s));
    let mut spins = 0;
    while s.adapt().unwrap().metrics.swap_rollbacks_total == 0 {
        let _ = s.tick(Instant::now()).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        spins += 1;
        assert!(spins < 2_000, "rollback never recorded");
    }
    let driver = s.adapt().unwrap();
    assert_eq!(driver.metrics.swaps_total, 0, "bad checkpoint must not commit");
    assert!(
        driver
            .faults
            .iter()
            .any(|f| matches!(f, TrainerFault::Io { message } if message.contains("rolled back"))),
        "rollback fault missing: {:?}",
        driver.faults
    );
    assert_eq!(toks, base_toks, "rollback leaked into served tokens");
}

/// Graceful drain kills an in-flight fine-tune instead of waiting it
/// out (cancel-on-drain), and the drained scheduler still answers every
/// accepted request.
#[test]
fn drain_cancels_inflight_trainer() {
    // Hang chaos: the run-0 subprocess sleeps far longer than any test
    // budget; only a cancel can clear it promptly.
    let plan = FaultPlan::default().trainer_hang_at(0);
    let acfg = adapt_cfg("drain", 2).with_chaos(plan.trainer.clone());
    let mut s = Scheduler::new(shifted_sim(0xD4A1), cfg(64)).with_adaptation(acfg);
    for i in 0..4i32 {
        s.submit(vec![i + 1, 9], 60).unwrap();
    }
    let mut ticks = 0;
    while !s.adapt().unwrap().trainer_running() {
        let _ = s.tick(Instant::now()).unwrap();
        ticks += 1;
        assert!(ticks < 10_000, "chaos trainer never launched");
    }
    s.drain();
    assert!(
        !s.adapt().unwrap().trainer_running(),
        "drain must cancel the in-flight fine-tune"
    );
    let mut done = 0usize;
    let mut spins = 0;
    while !s.is_idle() {
        done += s.tick(Instant::now()).unwrap().len();
        spins += 1;
        assert!(spins < 20_000, "drain did not converge");
    }
    assert_eq!(done, 4, "drained scheduler dropped sessions");
}
