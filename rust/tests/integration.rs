//! Integration tests over the PJRT runtime + engine. These need
//! `artifacts/` (built by `python3 -m compile.aot --out ../artifacts`
//! from `python/`); each test skips gracefully when artifacts are
//! absent so `cargo test` stays green pre-build.
//!
//! The heavyweight invariant here is greedy losslessness: at T=0,
//! speculative decoding must produce EXACTLY the vanilla greedy sequence
//! — any engine bookkeeping bug (positions, KV rollback, bonus-token
//! indices) breaks it immediately.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use lk_spec::data::corpus::{Corpus, CorpusSpec};
use lk_spec::eval::EvalMode;
use lk_spec::runtime::Runtime;
use lk_spec::server::batcher::BatcherConfig;
use lk_spec::server::engine::{AdaptiveOpts, EngineOpts, SpecEngine, VerifyPath};
use lk_spec::server::{DownshiftConfig, RequestResult, Scheduler};
use lk_spec::tensor::{read_checkpoint, HostTensor};
use lk_spec::train::{checkpoint_to_params, params_to_checkpoint, DraftTrainer, RunDirs, TargetTrainer};
use lk_spec::util::{Json, Pcg64};

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        println!("SKIP: artifacts missing (run python/compile/aot.py)");
        None
    }
}

/// Shared tiny work dir with a small corpus + quickly-trained dense-s
/// target and eagle3 draft (trained once per machine, reused below).
fn fixture(rt: &Runtime) -> (PathBuf, Corpus) {
    {
        let work = std::env::temp_dir().join("lkspec_itest");
        let data = work.join("data");
        let corpus = Corpus::generate(
            &data,
            &CorpusSpec {
                train_tokens: 30_000,
                eval_docs: 8,
                ..Default::default()
            },
        )
        .expect("corpus");
        let dirs = RunDirs::new(&work);
        if !dirs.target_ckpt("dense-s").exists() {
            let preset = lk_spec::config::TrainPreset {
                steps: 60,
                ..lk_spec::config::TrainPreset::target("dense-s")
            };
            TargetTrainer { rt, dirs: RunDirs::new(&work) }
                .train("dense-s", &corpus, &preset, 30)
                .expect("target train");
        }
        for arch in ["eagle3", "medusa", "mlp"] {
            if !dirs.draft_ckpt(&format!("{arch}_dense-s__kl")).exists() {
                let preset = lk_spec::config::TrainPreset {
                    steps: 40,
                    ..lk_spec::config::TrainPreset::draft("dense-s", arch)
                };
                DraftTrainer { rt, dirs: RunDirs::new(&work) }
                    .train(
                        &format!("{arch}@dense-s"),
                        &lk_spec::config::LossSpec::kl(),
                        &corpus,
                        &preset,
                        20,
                    )
                    .expect("draft train");
            }
        }
        (work, corpus)
    }
}

/// The eagle3 truncated-vocab map (None for full-vocab archs).
fn load_vocab_map(dirs: &RunDirs, arch: &str) -> Option<Vec<i32>> {
    if arch != "eagle3" {
        return None;
    }
    Some(
        Json::parse_file(&dirs.vocab_map())
            .unwrap()
            .get("map")
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect(),
    )
}

#[allow(clippy::too_many_arguments)]
fn engine_with<'rt>(
    rt: &'rt Runtime,
    work: &Path,
    draft: &str,
    mode: EvalMode,
    k: usize,
    seed: u64,
    verify_path: VerifyPath,
    adaptive: AdaptiveOpts,
) -> SpecEngine<'rt> {
    let dirs = RunDirs::new(work);
    let tckpt = read_checkpoint(&dirs.target_ckpt("dense-s")).unwrap();
    let arch = draft.split('@').next().unwrap();
    let dckpt = read_checkpoint(&dirs.draft_ckpt(&format!("{arch}_dense-s__kl"))).unwrap();
    let vm = load_vocab_map(&dirs, arch);
    SpecEngine::new(
        rt,
        draft,
        &tckpt,
        &dckpt,
        vm,
        EngineOpts {
            k_draft: k,
            temperature: 1.0,
            mode: mode.sampling(),
            seed,
            verify_path,
            tree: None,
            adaptive,
        },
    )
    .unwrap()
}

/// Fixed draft budget — what the parity / composition-independence
/// suites study (the adaptive suite opts into the live controller).
fn engine_for_draft<'rt>(
    rt: &'rt Runtime,
    work: &Path,
    draft: &str,
    mode: EvalMode,
    k: usize,
    seed: u64,
    verify_path: VerifyPath,
) -> SpecEngine<'rt> {
    engine_with(rt, work, draft, mode, k, seed, verify_path, AdaptiveOpts::fixed())
}

/// Like `engine_for_draft` but with the online speculation controller
/// LIVE (per-round K in 1..=k): what serving runs by default.
fn adaptive_engine_for_draft<'rt>(
    rt: &'rt Runtime,
    work: &Path,
    draft: &str,
    mode: EvalMode,
    k: usize,
    seed: u64,
    verify_path: VerifyPath,
) -> SpecEngine<'rt> {
    engine_with(rt, work, draft, mode, k, seed, verify_path, AdaptiveOpts::default())
}

/// Like `engine_for_draft` but decoding a candidate TREE per round:
/// a fixed `--tree FxF` topology, or (fanout = "auto") the controller's
/// per-round planned topologies.
fn tree_engine_for<'rt>(
    rt: &'rt Runtime,
    work: &Path,
    draft: &str,
    mode: EvalMode,
    fanout: &str,
    seed: u64,
    verify_path: VerifyPath,
) -> SpecEngine<'rt> {
    let dirs = RunDirs::new(work);
    let tckpt = read_checkpoint(&dirs.target_ckpt("dense-s")).unwrap();
    let arch = draft.split('@').next().unwrap();
    let dckpt = read_checkpoint(&dirs.draft_ckpt(&format!("{arch}_dense-s__kl"))).unwrap();
    let vm = load_vocab_map(&dirs, arch);
    let (tree, adaptive) = if fanout == "auto" {
        let auto = AdaptiveOpts {
            tree: true,
            ..Default::default()
        };
        (None, auto)
    } else {
        (
            Some(lk_spec::spec::sampling::TreeSpec::parse(fanout).unwrap()),
            AdaptiveOpts::fixed(),
        )
    };
    SpecEngine::new(
        rt,
        draft,
        &tckpt,
        &dckpt,
        vm,
        EngineOpts {
            temperature: 1.0,
            mode: mode.sampling(),
            seed,
            verify_path,
            tree,
            adaptive,
            ..Default::default()
        },
    )
    .unwrap()
}

fn engine_for<'rt>(
    rt: &'rt Runtime,
    work: &Path,
    mode: EvalMode,
    k: usize,
    seed: u64,
) -> SpecEngine<'rt> {
    engine_for_draft(rt, work, "eagle3@dense-s", mode, k, seed, VerifyPath::Auto)
}

/// One sequential suite: Runtime/PJRT state is !Send, and the fixture
/// (compiled executables, trained tiny checkpoints) is expensive, so the
/// engine-level checks share one runtime in a single #[test].
#[test]
fn engine_integration_suite() {
    let Some(p) = artifacts() else { return };
    let rt = Runtime::new(p).expect("runtime");
    let (work, corpus) = fixture(&rt);
    init_executables_produce_manifest_shapes(&rt);
    train_step_decreases_loss_from_scratch(&rt, &corpus);
    greedy_spec_equals_vanilla(&rt, &work, &corpus);
    stochastic_deterministic_given_seed(&rt, &work, &corpus);
    stochastic_composition_independent(&rt, &work, &corpus);
    batch_rows_independent(&rt, &work, &corpus);
    scheduler_join_matches_lockstep(&rt, &work, &corpus);
    scheduler_migration_device_gather_exact(&rt, &work, &corpus);
    device_verify_matches_host(&rt, &work, &corpus);
    adaptive_controller_greedy_exact(&rt, &work, &corpus);
    tree_decoding_suite(&rt, &work, &corpus);
    recurrent_tree_suite(&rt, &work, &corpus);
    k_sweep_shapes(&rt, &work, &corpus);
    greedy_draft_not_better(&rt, &work, &corpus);
    mtp_param_mapping(&rt);
}

// ---------------------------------------------------------------------------

fn init_executables_produce_manifest_shapes(rt: &Runtime) {
    println!("== init_executables_produce_manifest_shapes");
    for target in ["dense-s", "moe-s"] {
        let spec = rt.manifest.target(target).unwrap().clone();
        let init = rt.target_entry(target, "init").unwrap();
        let params = init
            .run(&[HostTensor::from_u32(&[2], &[1, 2])])
            .unwrap();
        assert_eq!(params.len(), spec.params.len());
        for (p, s) in params.iter().zip(&spec.params) {
            assert_eq!(p.shape, s.shape, "param {}", s.name);
        }
        // params must round-trip through the checkpoint layer
        let ck = params_to_checkpoint(&spec.params, &params, Json::Null);
        let back = checkpoint_to_params(&spec.params, &ck).unwrap();
        assert_eq!(back, params);
    }
}

fn train_step_decreases_loss_from_scratch(rt: &Runtime, corpus: &Corpus) {
    println!("== train_step_decreases_loss_from_scratch");
    // 25 fresh steps on dense-s must reduce LM loss vs step 1.
    let spec = rt.manifest.target("dense-s").unwrap().clone();
    let init = rt.target_entry("dense-s", "init").unwrap();
    let step_exe = rt.target_entry("dense-s", "train_step").unwrap();
    let mut params = init.run(&[HostTensor::from_u32(&[2], &[7, 8])]).unwrap();
    let mut m: Vec<HostTensor> = spec
        .params
        .iter()
        .map(|s| HostTensor::zeros(s.dtype, &s.shape))
        .collect();
    let mut v = m.clone();
    let ds = corpus
        .load(lk_spec::data::grammar::Domain::Math, "train")
        .unwrap();
    let mut rng = Pcg64::new(5, 5);
    let b = rt.manifest.train_batch;
    let w = rt.manifest.span + rt.manifest.k_heads + 2;
    let mut first = None;
    let mut last = 0.0;
    for step in 1..=25 {
        let tokens = HostTensor::from_i32(&[b, w], &ds.sample_batch(&mut rng, b, w));
        let mut args: Vec<HostTensor> = Vec::new();
        args.extend(params.iter().cloned());
        args.extend(m.iter().cloned());
        args.extend(v.iter().cloned());
        args.push(HostTensor::scalar_i32(step));
        args.push(tokens);
        args.push(HostTensor::scalar_f32(2e-3));
        let mut out = step_exe.run(&args).unwrap();
        let metrics = out.pop().unwrap().as_f32();
        let n = spec.params.len();
        v = out.split_off(2 * n);
        m = out.split_off(n);
        params = out;
        last = metrics[0];
        first.get_or_insert(metrics[0]);
    }
    assert!(
        last < first.unwrap() * 0.9,
        "loss {} -> {last} did not drop",
        first.unwrap()
    );
}

/// T=0 speculative decoding is LOSSLESS: byte-identical to vanilla greedy.
fn greedy_spec_equals_vanilla(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== greedy_spec_equals_vanilla");
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Code, "eval")
        .unwrap()
        .prompts(3, 12);
    let mut engine = engine_for(rt, work, EvalMode::T0, 7, 99);
    for p in &prompts {
        let spec = engine.generate_batch(std::slice::from_ref(p), 24).unwrap();
        let vanilla = engine.generate_vanilla(p, 24).unwrap();
        assert_eq!(
            spec[0].tokens[..24.min(spec[0].tokens.len())],
            vanilla.tokens[..24.min(vanilla.tokens.len())],
            "greedy speculative output diverged from vanilla greedy"
        );
    }
}

/// Stochastic decoding is reproducible from the seed and the engine
/// produces sane acceptance statistics.
fn stochastic_deterministic_given_seed(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== stochastic_deterministic_given_seed");
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Chat, "eval")
        .unwrap()
        .prompts(2, 12);
    // engines scoped one-at-a-time (PJRT CPU buffer lifetimes interact
    // badly with several live engines under load — see §Perf notes)
    let r1 = {
        let mut e1 = engine_for(rt, work, EvalMode::T1, 7, 1234);
        e1.generate_batch(&prompts, 24).unwrap()
    };
    let r2 = {
        let mut e2 = engine_for(rt, work, EvalMode::T1, 7, 1234);
        e2.generate_batch(&prompts, 24).unwrap()
    };
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.stats.tau(), b.stats.tau());
    }
    // different seed -> (almost surely) different sample path
    let r3 = {
        let mut e3 = engine_for(rt, work, EvalMode::T1, 7, 4321);
        e3.generate_batch(&prompts, 24).unwrap()
    };
    assert_ne!(r1[0].tokens, r3[0].tokens);
    // stats sanity
    let s = &r1[0].stats;
    assert!(s.rounds > 0);
    assert!(s.tau() >= 1.0 && s.tau() <= 8.0);
    let alphas = s.alpha_per_position();
    assert!(alphas.iter().all(|&a| (0.0..=1.0).contains(&a)));
}

/// Per-request RNG streams are keyed by stable request ids, so a
/// sequence's stochastic sample path is independent of batch
/// composition: one batch of 3 (ids 0..2) must equal three sequential
/// solo calls on a fresh engine (also ids 0..2). The old per-bootstrap
/// `next_seed` counter failed exactly this (padding rows consumed
/// seeds).
fn stochastic_composition_independent(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== stochastic_composition_independent");
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Chat, "eval")
        .unwrap()
        .prompts(3, 12);
    let batched = {
        let mut e = engine_for(rt, work, EvalMode::T1, 7, 31);
        e.generate_batch(&prompts, 20).unwrap()
    };
    let mut solo = Vec::new();
    {
        let mut e = engine_for(rt, work, EvalMode::T1, 7, 31);
        for p in &prompts {
            solo.push(e.generate_batch(std::slice::from_ref(p), 20).unwrap().remove(0));
        }
    }
    for (i, (a, b)) in batched.iter().zip(&solo).enumerate() {
        assert_eq!(
            a.tokens, b.tokens,
            "request {i}: tokens depend on batch composition"
        );
        assert_eq!(a.stats.accepted, b.stats.accepted, "request {i} stats");
    }
}

/// Continuous batching on the REAL engine: a queued request joins the
/// decode group mid-flight (one-row KV copy + per-row prefill) after
/// another sequence finishes, and every session's tokens and
/// per-position acceptance stats are identical to the lockstep
/// run-to-completion path with the same seed/request ids.
fn scheduler_join_matches_lockstep(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== scheduler_join_matches_lockstep");
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Chat, "eval")
        .unwrap()
        .prompts(5, 12);
    assert!(prompts.len() >= 5, "need 5 eval prompts");
    let caps = [6usize, 28, 28, 28, 12];
    let cfg = BatcherConfig {
        buckets: rt.manifest.serve_batches.clone(),
        max_wait: Duration::ZERO,
        queue_cap: 16,
    };

    // --- continuous path: 4 upfront, the 5th submitted after the first
    // session finishes, so it can only be served via a mid-flight join.
    let engine = engine_for(rt, work, EvalMode::T1, 7, 77);
    let mut sched = Scheduler::new(engine, cfg);
    for i in 0..4 {
        sched.submit(prompts[i].clone(), caps[i]).unwrap();
    }
    let mut got: BTreeMap<u64, RequestResult> = BTreeMap::new();
    let mut guard = 0;
    while got.is_empty() {
        for (id, r) in sched.tick(Instant::now()).unwrap() {
            got.insert(id, r);
        }
        guard += 1;
        assert!(guard < 500, "no session finished");
    }
    sched.submit(prompts[4].clone(), caps[4]).unwrap();
    while !sched.is_idle() {
        for (id, r) in sched.tick(Instant::now()).unwrap() {
            got.insert(id, r);
        }
        guard += 1;
        assert!(guard < 2000, "scheduler did not converge");
    }
    assert_eq!(got.len(), 5);
    assert!(
        sched.metrics.joins >= 1,
        "expected the late request to join mid-flight"
    );
    assert!(sched.metrics.slot_occupancy.mean() > 0.0);

    // --- lockstep reference: same seed, same request ids (0..3 then 4).
    let mut e2 = engine_for(rt, work, EvalMode::T1, 7, 77);
    let reqs: Vec<(Vec<i32>, usize)> = (0..4).map(|i| (prompts[i].clone(), caps[i])).collect();
    let mut reference = e2.generate_batch_with(&reqs).unwrap();
    reference.extend(
        e2.generate_batch_with(&[(prompts[4].clone(), caps[4])])
            .unwrap(),
    );
    for (i, b) in reference.iter().enumerate() {
        let a = &got[&(i as u64)];
        assert_eq!(
            a.tokens, b.tokens,
            "session {i}: continuous path diverged from lockstep"
        );
        assert_eq!(
            a.stats.drafted, b.stats.drafted,
            "session {i}: per-position drafted counts differ"
        );
        assert_eq!(
            a.stats.accepted, b.stats.accepted,
            "session {i}: per-position acceptance stats differ"
        );
        assert_eq!(a.stats.prefix_hist, b.stats.prefix_hist, "session {i}");
    }
}

/// Cross-bucket migration through the device gather entry
/// (`kv_gather_rows_b{Bsrc}x{Bdst}` + the `dkv_` twin for recurrent
/// drafts) is EXACT and host-free. A downshift (4 -> 1) and an upshift
/// (1 -> 4, with padding clones) both fire on the real engine, and every
/// session's tokens and per-position acceptance stats stay bit-identical
/// to the lockstep run-to-completion reference — across the three chain
/// backends in greedy and stochastic modes. The engine's migration
/// ledger must report ZERO KV bytes through the host (only the small
/// [B, d]-shaped conditioning carries round-trip).
fn scheduler_migration_device_gather_exact(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== scheduler_migration_device_gather_exact");
    if !rt.has_target_entry("dense-s", "kv_gather_rows_b4x1") {
        println!("SKIP: artifacts predate the kv gather entries");
        return;
    }
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Chat, "eval")
        .unwrap()
        .prompts(5, 12);
    let caps = [40usize, 6, 6, 6, 8]; // one long tail + three shorts + a late joiner
    for (draft, mode) in [
        ("eagle3@dense-s", EvalMode::T0),
        ("eagle3@dense-s", EvalMode::T1),
        ("medusa@dense-s", EvalMode::T1),
        ("mlp@dense-s", EvalMode::T0),
    ] {
        if draft == "eagle3@dense-s" && !rt.has_draft_entry(draft, "dkv_gather_rows_b4x1") {
            println!("SKIP {draft}: artifacts lack the dkv gather twin");
            continue;
        }
        let cfg = BatcherConfig {
            buckets: rt.manifest.serve_batches.clone(),
            max_wait: Duration::ZERO,
            queue_cap: 16,
        };
        let engine = engine_for_draft(rt, work, draft, mode, 6, 83, VerifyPath::Auto);
        let ds = DownshiftConfig {
            enabled: true,
            after_rounds: 2,
        };
        let mut sched = Scheduler::with_downshift(engine, cfg, ds);
        for i in 0..4 {
            sched.submit(prompts[i].clone(), caps[i]).unwrap();
        }
        // Run until the long tail has been downshifted to b=1…
        let mut got: BTreeMap<u64, RequestResult> = BTreeMap::new();
        let mut guard = 0;
        while sched.metrics.downshifts == 0 {
            for (id, r) in sched.tick(Instant::now()).unwrap() {
                got.insert(id, r);
            }
            guard += 1;
            assert!(guard < 1000, "{draft} {mode:?}: downshift never fired");
        }
        // …then a late arrival forces the mirror upshift (1 -> 4 with
        // padding clones in the row map).
        sched.submit(prompts[4].clone(), caps[4]).unwrap();
        while !sched.is_idle() {
            for (id, r) in sched.tick(Instant::now()).unwrap() {
                got.insert(id, r);
            }
            guard += 1;
            assert!(guard < 2000, "{draft} {mode:?}: scheduler did not converge");
        }
        assert_eq!(got.len(), 5, "{draft} {mode:?}");
        assert!(sched.metrics.downshifts >= 1, "{draft} {mode:?}");
        assert!(sched.metrics.upshifts >= 1, "{draft} {mode:?}");
        let em = &sched.core().metrics;
        assert!(em.migrations >= 2, "{draft} {mode:?}: both shifts must migrate");
        assert_eq!(
            em.host_kv_bytes_per_migration(),
            0.0,
            "{draft} {mode:?}: migration moved KV bytes through the host"
        );

        // Lockstep reference: same seed, same request ids.
        let mut e2 = engine_for_draft(rt, work, draft, mode, 6, 83, VerifyPath::Auto);
        let reqs: Vec<(Vec<i32>, usize)> =
            (0..4).map(|i| (prompts[i].clone(), caps[i])).collect();
        let mut reference = e2.generate_batch_with(&reqs).unwrap();
        reference.extend(
            e2.generate_batch_with(&[(prompts[4].clone(), caps[4])])
                .unwrap(),
        );
        for (i, b) in reference.iter().enumerate() {
            let a = &got[&(i as u64)];
            assert_eq!(
                a.tokens, b.tokens,
                "{draft} {mode:?} session {i}: migrated decode diverged from lockstep"
            );
            assert_eq!(a.stats.drafted, b.stats.drafted, "{draft} {mode:?} session {i}");
            assert_eq!(a.stats.accepted, b.stats.accepted, "{draft} {mode:?} session {i}");
            assert_eq!(a.stats.prefix_hist, b.stats.prefix_hist, "{draft} {mode:?} session {i}");
        }
    }
}

/// THE golden-uniform parity check for the device-resident verify: with
/// the same seed both paths draw the same fixed-count uniforms in the
/// same stream order, so forced-host and forced-device engines must emit
/// identical tokens and identical per-position acceptance statistics
/// (n_accepted / accepted drafts / bonus tokens) — for all three draft
/// architectures and in every sampling mode.
///
/// Both paths use identical per-element formulations; the only residual
/// divergence is f32 reduction ordering (XLA vs serial sums), which
/// could flip a verdict only when a uniform lands within ~1 ulp of a
/// CDF/acceptance boundary. At this test's scale (a few hundred
/// decisions) that is a ~0 probability event; if it ever fires, suspect
/// a real formulation drift first.
fn device_verify_matches_host(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== device_verify_matches_host");
    if !rt.has_target_entry("dense-s", "verify_fused_b1") {
        println!("SKIP: artifacts predate the device verify entries");
        return;
    }
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Chat, "eval")
        .unwrap()
        .prompts(2, 12);
    for draft in ["eagle3@dense-s", "medusa@dense-s", "mlp@dense-s"] {
        for mode in [EvalMode::T1, EvalMode::T0, EvalMode::T1GreedyDraft] {
            let host = {
                let mut e =
                    engine_for_draft(rt, work, draft, mode, 6, 55, VerifyPath::Host);
                assert_eq!(e.verify_path(), "host");
                e.generate_batch(&prompts, 20).unwrap()
            };
            let dev = {
                let mut e =
                    engine_for_draft(rt, work, draft, mode, 6, 55, VerifyPath::Device);
                assert_eq!(e.verify_path(), "device");
                let out = e.generate_batch(&prompts, 20).unwrap();
                // the whole point: no full-vocab pulls in steady state
                assert!(
                    e.metrics.bytes_to_host_per_round() < 1024.0,
                    "{draft} {mode:?}: device path pulled {} B/round",
                    e.metrics.bytes_to_host_per_round()
                );
                out
            };
            for (i, (a, b)) in host.iter().zip(&dev).enumerate() {
                assert_eq!(
                    a.tokens, b.tokens,
                    "{draft} {mode:?} request {i}: device tokens diverge from host"
                );
                assert_eq!(a.stats.drafted, b.stats.drafted, "{draft} {mode:?} req {i}");
                assert_eq!(
                    a.stats.accepted, b.stats.accepted,
                    "{draft} {mode:?} req {i}"
                );
                assert_eq!(
                    a.stats.prefix_hist, b.stats.prefix_hist,
                    "{draft} {mode:?} req {i}"
                );
            }
        }
    }
}

/// Satellite: adaptive-K exactness on the real engine. In greedy mode
/// (T0) every emitted position is the target's greedy token, so
/// enabling the speculation controller can change ROUND COUNTS but
/// never the emitted sequence — checked against the fixed-K engine for
/// all three chain backends on both verify paths (the fused entries
/// take k_active as a runtime scalar, so the device path needs no
/// re-lowering to decode round-varying chains).
fn adaptive_controller_greedy_exact(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== adaptive_controller_greedy_exact");
    let device_ready = rt.has_target_entry("dense-s", "verify_fused_b1");
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Chat, "eval")
        .unwrap()
        .prompts(2, 12);
    for draft in ["eagle3@dense-s", "medusa@dense-s", "mlp@dense-s"] {
        for path in [VerifyPath::Host, VerifyPath::Device] {
            if path == VerifyPath::Device && !device_ready {
                println!("SKIP device: artifacts predate the fused entries");
                continue;
            }
            let fixed = {
                let mut e = engine_for_draft(rt, work, draft, EvalMode::T0, 6, 91, path);
                e.generate_batch(&prompts, 24).unwrap()
            };
            let adaptive = {
                let mut e =
                    adaptive_engine_for_draft(rt, work, draft, EvalMode::T0, 6, 91, path);
                assert!(e.adaptive(), "controller should be live");
                e.generate_batch(&prompts, 24).unwrap()
            };
            for (i, (a, b)) in fixed.iter().zip(&adaptive).enumerate() {
                assert_eq!(
                    a.tokens, b.tokens,
                    "{draft} {path:?} request {i}: controller changed greedy tokens"
                );
            }
        }
    }
}

/// Multi-candidate decoding on the real engine (medusa 2x2 tree).
/// Three invariants:
///   1. greedy tree decoding is LOSSLESS — byte-identical to vanilla
///      greedy (tree attention, the walk, and the KV path splice must
///      all be exact for this to hold);
///   2. forced-host and forced-device tree engines emit identical
///      tokens and per-level acceptance stats from the same seed
///      (golden-uniform parity through the verify_tree_fused graph);
///   3. the device path keeps per-round host traffic at O(B·N) ints.
fn tree_decoding_suite(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== tree_decoding_suite");
    if !rt.has_target_entry("dense-s", "verify_tree_b1") {
        println!("SKIP: artifacts predate the tree verify entries");
        return;
    }
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Chat, "eval")
        .unwrap()
        .prompts(3, 12);

    // --- greedy losslessness ------------------------------------------
    {
        let mut e = tree_engine_for(
            rt, work, "medusa@dense-s", EvalMode::T0, "2x2", 19, VerifyPath::Host,
        );
        assert_eq!(e.backend_name(), "medusa-tree");
        for p in prompts.iter().take(2) {
            let spec = e.generate_batch(std::slice::from_ref(p), 20).unwrap();
            let vanilla = e.generate_vanilla(p, 20).unwrap();
            let n = 20.min(spec[0].tokens.len()).min(vanilla.tokens.len());
            assert_eq!(
                spec[0].tokens[..n],
                vanilla.tokens[..n],
                "greedy tree decoding diverged from vanilla greedy"
            );
        }
    }

    // --- host/device golden-uniform parity ----------------------------
    let device_ready = rt.has_target_entry("dense-s", "verify_tree_fused_b1")
        && rt.has_draft_entry("medusa@dense-s", "propose_tree_sample_b1");
    if !device_ready {
        println!("SKIP: artifacts lack the fused tree entries");
        return;
    }
    for mode in [EvalMode::T1, EvalMode::T0, EvalMode::T1GreedyDraft] {
        let host = {
            let mut e = tree_engine_for(
                rt, work, "medusa@dense-s", mode, "2x2", 57, VerifyPath::Host,
            );
            assert_eq!(e.verify_path(), "host");
            e.generate_batch(&prompts, 20).unwrap()
        };
        let dev = {
            let mut e = tree_engine_for(
                rt, work, "medusa@dense-s", mode, "2x2", 57, VerifyPath::Device,
            );
            assert_eq!(e.verify_path(), "device");
            let out = e.generate_batch(&prompts, 20).unwrap();
            assert!(
                e.metrics.bytes_to_host_per_round() < 1024.0,
                "tree {mode:?}: device path pulled {} B/round",
                e.metrics.bytes_to_host_per_round()
            );
            assert!(e.metrics.nodes_per_round() > 5.9, "2x2 tree drafts 6 nodes");
            out
        };
        for (i, (a, b)) in host.iter().zip(&dev).enumerate() {
            assert_eq!(
                a.tokens, b.tokens,
                "tree {mode:?} request {i}: device tokens diverge from host"
            );
            assert_eq!(a.stats.accepted, b.stats.accepted, "tree {mode:?} req {i}");
            assert_eq!(
                a.stats.prefix_hist, b.stats.prefix_hist,
                "tree {mode:?} req {i}"
            );
        }
    }

    // --- tree vs chain: acceptance length should not degrade ----------
    let chain_tau: f64 = {
        let mut e = engine_for_draft(
            rt, work, "medusa@dense-s", EvalMode::T1, 2, 7, VerifyPath::Auto,
        );
        let r = e.generate_batch(&prompts, 24).unwrap();
        r.iter().map(|x| x.stats.tokens_per_round()).sum::<f64>() / r.len() as f64
    };
    let tree_tau: f64 = {
        let mut e = tree_engine_for(
            rt, work, "medusa@dense-s", EvalMode::T1, "2x2", 7, VerifyPath::Auto,
        );
        let r = e.generate_batch(&prompts, 24).unwrap();
        r.iter().map(|x| x.stats.tokens_per_round()).sum::<f64>() / r.len() as f64
    };
    println!("   tokens/round: chain-k2 {chain_tau:.3} vs tree-2x2 {tree_tau:.3}");
    assert!(
        tree_tau >= chain_tau - 0.35,
        "2x2 tree ({tree_tau:.3} tok/round) far below the depth-2 chain ({chain_tau:.3})"
    );
}

/// Tree decoding on the STATEFUL drafter (recurrent-tree over eagle3):
/// the per-path draft-KV machinery end to end. Four invariants:
///   1. greedy tree decoding is LOSSLESS — byte-identical to vanilla
///      greedy (the level-parallel expansion, the per-path draft-KV
///      writes, the dkv path splice and the path-gathered extend must
///      all be exact for this to hold);
///   2. forced-host and forced-device recurrent-tree engines emit
///      identical tokens and per-level stats from the same seed
///      (golden-uniform parity through propose_tree_sample /
///      verify_tree_fused / extend_tree_sample);
///   3. the device path keeps per-round host traffic at O(B·N) ints;
///   4. `--tree auto` plans topologies for it through a CHAINED
///      (non-zero per-level) cost model — the ISSUE-5 criterion — and
///      stays greedy-lossless while adapting.
fn recurrent_tree_suite(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== recurrent_tree_suite");
    if !rt.has_target_entry("dense-s", "verify_tree_b1")
        || !rt.has_draft_entry("eagle3@dense-s", "tree_step_b1")
        || !rt.has_draft_entry("eagle3@dense-s", "dkv_path_gather_b1")
    {
        println!("SKIP: artifacts predate the recurrent tree entries");
        return;
    }
    let prompts = &corpus
        .load(lk_spec::data::grammar::Domain::Chat, "eval")
        .unwrap()
        .prompts(3, 12);

    // --- greedy losslessness (host path) -------------------------------
    {
        let mut e = tree_engine_for(
            rt, work, "eagle3@dense-s", EvalMode::T0, "2x2", 23, VerifyPath::Host,
        );
        assert_eq!(e.backend_name(), "recurrent-tree");
        for p in prompts.iter().take(2) {
            let spec = e.generate_batch(std::slice::from_ref(p), 20).unwrap();
            let vanilla = e.generate_vanilla(p, 20).unwrap();
            let n = 20.min(spec[0].tokens.len()).min(vanilla.tokens.len());
            assert_eq!(
                spec[0].tokens[..n],
                vanilla.tokens[..n],
                "greedy recurrent-tree decoding diverged from vanilla greedy"
            );
        }
    }

    // --- host/device golden-uniform parity -----------------------------
    let device_ready = rt.has_target_entry("dense-s", "verify_tree_fused_b1")
        && rt.has_draft_entry("eagle3@dense-s", "propose_tree_sample_b1")
        && rt.has_draft_entry("eagle3@dense-s", "extend_tree_sample_b1");
    if device_ready {
        for mode in [EvalMode::T1, EvalMode::T0, EvalMode::T1GreedyDraft] {
            let host = {
                let mut e = tree_engine_for(
                    rt, work, "eagle3@dense-s", mode, "2x2", 61, VerifyPath::Host,
                );
                assert_eq!(e.verify_path(), "host");
                e.generate_batch(prompts, 20).unwrap()
            };
            let dev = {
                let mut e = tree_engine_for(
                    rt, work, "eagle3@dense-s", mode, "2x2", 61, VerifyPath::Device,
                );
                assert_eq!(e.verify_path(), "device");
                let out = e.generate_batch(prompts, 20).unwrap();
                assert!(
                    e.metrics.bytes_to_host_per_round() < 1024.0,
                    "recurrent tree {mode:?}: device path pulled {} B/round",
                    e.metrics.bytes_to_host_per_round()
                );
                out
            };
            for (i, (a, b)) in host.iter().zip(&dev).enumerate() {
                assert_eq!(
                    a.tokens, b.tokens,
                    "recurrent tree {mode:?} request {i}: device tokens \
                     diverge from host"
                );
                assert_eq!(
                    a.stats.accepted, b.stats.accepted,
                    "recurrent tree {mode:?} req {i}"
                );
                assert_eq!(
                    a.stats.prefix_hist, b.stats.prefix_hist,
                    "recurrent tree {mode:?} req {i}"
                );
            }
        }
    } else {
        println!("SKIP parity: artifacts lack the fused recurrent tree entries");
    }

    // --- `--tree auto`: controller-planned topologies on the chained
    // cost model (host path: depth is priced per tree_step dispatch;
    // device path: the one-graph expansion is depth-invariant, so the
    // engine folds the per-level price into the fixed term) -------------
    {
        let mut e = tree_engine_for(
            rt, work, "eagle3@dense-s", EvalMode::T0, "auto", 29, VerifyPath::Host,
        );
        assert_eq!(e.backend_name(), "recurrent-tree");
        assert!(e.adaptive(), "auto topologies need the live controller");
        assert!(
            e.controller().cfg().cost.per_token > 0.0,
            "recurrent-tree must plan through a chained cost model (host)"
        );
        assert!(e.tree_plan().is_some(), "auto mode must hold a planned tree");
        for p in prompts.iter().take(2) {
            let spec = e.generate_batch(std::slice::from_ref(p), 20).unwrap();
            let vanilla = e.generate_vanilla(p, 20).unwrap();
            let n = 20.min(spec[0].tokens.len()).min(vanilla.tokens.len());
            assert_eq!(
                spec[0].tokens[..n],
                vanilla.tokens[..n],
                "auto-planned recurrent tree diverged from vanilla greedy"
            );
        }
    }
    if device_ready {
        let e = tree_engine_for(
            rt, work, "eagle3@dense-s", EvalMode::T0, "auto", 29, VerifyPath::Device,
        );
        let cost = e.controller().cfg().cost;
        assert!(
            cost.per_token == 0.0 && cost.fixed > 0.0,
            "device tree rounds are depth-invariant: the chained price \
             must be folded into the fixed term (got {cost:?})"
        );
    }
}

/// Batched lockstep decoding must give each sequence the same results it
/// would get alone (same seed -> same tokens), proving per-row position
/// handling and padding isolation.
fn batch_rows_independent(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== batch_rows_independent");
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Math, "eval")
        .unwrap()
        .prompts(3, 12);
    // batch of 3 (padded to bucket 4)
    let mut eb = engine_for(rt, work, EvalMode::T0, 7, 7);
    let batch = eb.generate_batch(&prompts, 20).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        let mut e1 = engine_for(rt, work, EvalMode::T0, 7, 7);
        let solo = e1.generate_batch(std::slice::from_ref(p), 20).unwrap();
        assert_eq!(
            batch[i].tokens, solo[0].tokens,
            "row {i} diverges between batched and solo decoding"
        );
    }
}

/// K sweep: τ is computed against the requested chain length.
fn k_sweep_shapes(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== k_sweep_shapes");
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Code, "eval")
        .unwrap()
        .prompts(2, 12);
    for k in [1usize, 3, 7] {
        let mut e = engine_for(rt, work, EvalMode::T1, k, 11);
        assert_eq!(e.k_draft(), k);
        let r = e.generate_batch(&prompts, 16).unwrap();
        assert_eq!(r[0].stats.k, k);
        assert!(r[0].stats.tau() <= k as f64 + 1.0 + 1e-9);
    }
}

/// Greedy-draft (Appendix D) must not raise acceptance above exact
/// rejection sampling on the same engine/seed/domain.
fn greedy_draft_not_better(rt: &Runtime, work: &Path, corpus: &Corpus) {
    println!("== greedy_draft_not_better");
    let prompts = corpus
        .load(lk_spec::data::grammar::Domain::Chat, "eval")
        .unwrap()
        .prompts(4, 12);
    let re = {
        let mut exact = engine_for(rt, work, EvalMode::T1, 7, 3);
        exact.generate_batch(&prompts, 32).unwrap()
    };
    let rb = {
        let mut buggy = engine_for(rt, work, EvalMode::T1GreedyDraft, 7, 3);
        buggy.generate_batch(&prompts, 32).unwrap()
    };
    let tau_e: f64 = re.iter().map(|r| r.stats.tau()).sum::<f64>() / re.len() as f64;
    let tau_b: f64 = rb.iter().map(|r| r.stats.tau()).sum::<f64>() / rb.len() as f64;
    assert!(
        tau_e >= tau_b - 0.35,
        "exact {tau_e:.3} unexpectedly far below greedy-draft {tau_b:.3}"
    );
}

/// mtp draft params restructure from the target checkpoint by name.
fn mtp_param_mapping(rt: &Runtime) {
    println!("== mtp_param_mapping");
    let dspec = rt.manifest.draft("mtp@mtp-l").unwrap().clone();
    let tspec = rt.manifest.target("mtp-l").unwrap().clone();
    let init = rt.target_entry("mtp-l", "init").unwrap();
    let tparams = init.run(&[HostTensor::from_u32(&[2], &[3, 4])]).unwrap();
    let tck = params_to_checkpoint(&tspec.params, &tparams, Json::Null);
    let dparams = lk_spec::train::mtp_params_from_target(&dspec.params, &tck).unwrap();
    assert_eq!(dparams.len(), dspec.params.len());
    // fc_in must be the target's mtp/proj verbatim
    let idx = dspec.params.iter().position(|s| s.name == "fc_in").unwrap();
    assert_eq!(&dparams[idx], tck.get("mtp/proj").unwrap());
    // fc_fuse is the identity
    let idx = dspec.params.iter().position(|s| s.name == "fc_fuse").unwrap();
    let eye = dparams[idx].as_f32();
    let d = dspec.params[idx].shape[0];
    for i in 0..d {
        for j in 0..d {
            assert_eq!(eye[i * d + j], if i == j { 1.0 } else { 0.0 });
        }
    }
}
