//! Chunked-prefill scenario suite over `SimCore` (DESIGN.md §11) —
//! PJRT-free, so it runs everywhere `cargo test` does, including the
//! CI smoke step.
//!
//! The in-module lane tests in `server/scheduler.rs` pin the keystone
//! invariants one prompt at a time; this suite drives BURSTY mixes —
//! several long prompts landing against a live decode cohort — and
//! checks the properties the bench relies on:
//!
//!   * chunked-prefill decode is bit-equal to whole-prompt joins for
//!     every session in the mix (greedy AND stochastic: `SimCore`
//!     draws per-session RNG streams, so equality of token streams
//!     means the chunk schedule never perturbed a single draw);
//!   * no tick ever runs more prefill chunks than the arbiter budget,
//!     and decode rounds keep advancing while a burst amortizes;
//!   * under the radix prefix cache, shared-prefix bursts skip cached
//!     chunks as COMPUTE (accounted in `prefill_tokens_saved`);
//!   * a fault inside one session's prefill chunk evicts only that
//!     session, even mid-burst.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use lk_spec::server::batcher::BatcherConfig;
use lk_spec::server::{PagedKvConfig, RequestError, RequestResult, Scheduler, SimCore};
use lk_spec::spec::adaptive::{CostModel, PrefillArbiter, PrefillArbiterCfg};

fn cfg(queue_cap: usize) -> BatcherConfig {
    BatcherConfig {
        buckets: vec![1, 4],
        max_wait: Duration::ZERO,
        queue_cap,
    }
}

fn arb(chunk: usize, cap: usize) -> PrefillArbiter {
    PrefillArbiter::new(PrefillArbiterCfg {
        max_chunks_per_round: cap,
        ..PrefillArbiterCfg::for_chunk(chunk, 8, CostModel::chained(0.25), 4)
    })
}

fn paged_cfg(total_blocks: usize) -> PagedKvConfig {
    PagedKvConfig {
        block_size: 4,
        total_blocks,
        prefix_cache: true,
    }
}

/// Tick until idle, collecting results; panics if the scheduler fails
/// to converge within `guard` ticks.
fn drain(s: &mut Scheduler<SimCore>, guard: usize) -> Vec<(u64, RequestResult)> {
    let mut out = Vec::new();
    let mut ticks = 0;
    while !s.is_idle() {
        out.extend(s.tick(Instant::now()).unwrap());
        ticks += 1;
        assert!(ticks < guard, "scheduler did not converge");
    }
    out
}

/// A bursty long-prompt scenario: a b=4 decode cohort is live, then
/// `burst` long prompts (staggered lengths) arrive over several ticks.
/// Returns per-id results plus the lane's chunk count.
fn run_burst(
    seed: u64,
    chunk: Option<usize>,
    budget: usize,
    burst: usize,
) -> (BTreeMap<u64, RequestResult>, u64) {
    let mut core = SimCore::new(4, seed, vec![1, 4]);
    if let Some(c) = chunk {
        core = core.with_chunked_prefill(c);
    }
    let mut s = Scheduler::new(core, cfg(64));
    if let Some(c) = chunk {
        s = s.with_chunked_prefill(arb(c, budget));
    }
    // Cohort: one long-running keeper + three short sessions.
    s.submit(vec![1, 7], 48).unwrap();
    for i in 1..4 {
        s.submit(vec![i + 1, 7], 5).unwrap();
    }
    let _ = s.tick(Instant::now()).unwrap();
    // The burst: long prompts with staggered lengths, two per tick, so
    // the lane has to multiplex sessions mid-prefill.
    for (n, w) in (0..burst).zip([24usize, 40, 32, 48, 28, 36].iter().cycle()) {
        let base = 200 + 100 * n as i32;
        s.submit((base..base + *w as i32).collect(), 6).unwrap();
        if n % 2 == 1 {
            let _ = s.tick(Instant::now()).unwrap();
        }
    }
    let mut got = BTreeMap::new();
    for (id, r) in drain(&mut s, 20_000) {
        got.insert(id, r);
    }
    (got, s.core().prefill_chunks_run)
}

/// THE scenario the bench measures, as a correctness property: a burst
/// of long prompts against a live cohort, chunked vs whole-prompt —
/// every session's tokens and acceptance stats are bit-equal. Swept
/// over seeds, chunk lengths, and budgets so the equality is a
/// property of the lane, not of one lucky schedule.
#[test]
fn bursty_long_prompt_mix_bit_equal_across_chunk_schedules() {
    for seed in [42u64, 7, 1234] {
        let (whole, whole_chunks) = run_burst(seed, None, 0, 4);
        assert_eq!(whole_chunks, 0);
        for (chunk, budget) in [(4usize, 1usize), (4, 2), (8, 2), (2, 4)] {
            let (chunked, lane_chunks) = run_burst(seed, Some(chunk), budget, 4);
            assert!(lane_chunks > 0, "burst never used the lane (c={chunk})");
            assert_eq!(
                chunked.len(),
                whole.len(),
                "session count diverged (seed {seed}, c={chunk}, budget {budget})"
            );
            for (id, w) in &whole {
                let c = &chunked[id];
                assert_eq!(
                    c.tokens, w.tokens,
                    "tokens diverged: seed {seed}, c={chunk}, budget {budget}, id {id}"
                );
                assert_eq!(c.stats.drafted, w.stats.drafted, "id {id}");
                assert_eq!(c.stats.accepted, w.stats.accepted, "id {id}");
                assert_eq!(c.stats.prefix_hist, w.stats.prefix_hist, "id {id}");
            }
        }
    }
}

/// Decode cadence under a burst: with six long prompts queued behind a
/// live cohort, no tick runs more chunks than the budget, decode
/// rounds advance EVERY tick, and the keeper's token stream never goes
/// quiet while the lane is backed up.
#[test]
fn burst_never_stalls_decode_beyond_chunk_budget() {
    let core = SimCore::new(4, 42, vec![1, 4]).with_chunked_prefill(4);
    let mut s = Scheduler::new(core, cfg(64)).with_chunked_prefill(arb(4, 2));
    let keeper = s.submit(vec![1, 7], 120).unwrap();
    let _ = s.tick(Instant::now()).unwrap();
    let _ = s.take_token_events();
    // Six long prompts land at once: 6 * 10 = 60 chunks of backlog.
    for n in 0..6 {
        let base = 200 + 100 * n;
        s.submit((base..base + 40).collect(), 4).unwrap();
    }
    let mut done = Vec::new();
    let mut ticks = 0usize;
    let mut quiet = 0usize;
    while !s.is_idle() {
        let chunks0 = s.core().prefill_chunks_run;
        let rounds0 = s.core().rounds_run;
        done.extend(s.tick(Instant::now()).unwrap());
        assert!(
            s.core().prefill_chunks_run - chunks0 <= 2,
            "tick {ticks} ran more chunks than the budget"
        );
        assert!(s.core().rounds_run > rounds0, "decode stalled on tick {ticks}");
        // The keeper must keep streaming: it may skip a tick while the
        // group re-forms around joins, but never goes quiet for long.
        if s.take_token_events().iter().any(|(id, t)| *id == keeper && !t.is_empty()) {
            quiet = 0;
        } else if done.iter().all(|(id, _)| *id != keeper) {
            quiet += 1;
            assert!(quiet < 8, "keeper stream went quiet behind the burst");
        }
        ticks += 1;
        assert!(ticks < 10_000, "burst did not converge");
    }
    assert_eq!(s.core().prefill_chunks_run, 60, "6 prompts x 10 chunks");
    assert!(s.metrics.prefill_lane_rounds >= 30, "60 chunks at <= 2/tick");
}

/// A shared-prefix burst under the radix cache: the first long session
/// prefills in full; the rest skip every cache-resident chunk as
/// compute. Saved tokens scale with the burst, and the lane runs far
/// fewer chunks than the uncached control.
#[test]
fn shared_prefix_burst_skips_cached_chunks() {
    let shared: Vec<i32> = (500..532).collect(); // 32 tokens = 8 chunks
    let run = |prefix_cache: bool| {
        let core = SimCore::new(4, 42, vec![1, 4]).with_chunked_prefill(4);
        let mut s = Scheduler::new(core, cfg(64))
            .with_paged_kv(PagedKvConfig {
                prefix_cache,
                ..paged_cfg(128)
            })
            .with_chunked_prefill(arb(4, 4));
        s.submit(vec![1, 7], 60).unwrap();
        let _ = s.tick(Instant::now()).unwrap();
        // Four sessions share the 32-token prefix, arriving as a burst.
        for _ in 0..4 {
            s.submit(shared.clone(), 4).unwrap();
            let _ = s.tick(Instant::now()).unwrap();
        }
        let n = drain(&mut s, 20_000).len();
        assert_eq!(n, 5);
        (
            s.core().prefill_chunks_run,
            s.metrics.prefill_tokens_saved,
            s.metrics.prefill_tokens,
        )
    };
    let (cold_chunks, cold_saved, _) = run(false);
    let (warm_chunks, warm_saved, warm_tokens) = run(true);
    assert_eq!(cold_saved, 0);
    // Warm: each of the 3 followers skips 7 of its 8 chunks (the final
    // chunk always runs — its logits seed the first sampled token).
    assert_eq!(warm_saved, 3 * 28, "three followers x 28 cached tokens");
    assert_eq!(
        cold_chunks - warm_chunks,
        3 * 7,
        "cache must remove whole chunks of lane compute"
    );
    // Accounting identity: executed + saved covers every prompt token.
    assert_eq!(warm_tokens + warm_saved, 2 + 32 + 4 * 32);
}

/// Chaos mid-burst: one session faults during its prefill chunk. Only
/// that session is evicted; every other session in the burst — and the
/// decoding cohort — finishes bit-equal to the unfaulted run.
#[test]
fn prefill_fault_mid_burst_contains_blast_radius() {
    let run = |fail: Option<u64>| {
        let core = SimCore::new(4, 42, vec![1, 4]).with_chunked_prefill(4);
        let mut s = Scheduler::new(core, cfg(64))
            .with_paged_kv(paged_cfg(128))
            .with_chunked_prefill(arb(4, 2));
        s.submit(vec![1, 7], 40).unwrap();
        let _ = s.tick(Instant::now()).unwrap();
        s.core_mut().fail_prefill_at = fail;
        let mut ids = Vec::new();
        for n in 0..3 {
            let base = 200 + 100 * n;
            ids.push(s.submit((base..base + 24).collect(), 6).unwrap());
            let _ = s.tick(Instant::now()).unwrap();
        }
        let mut got = BTreeMap::new();
        let mut failures = Vec::new();
        let mut ticks = 0;
        while !s.is_idle() {
            for (id, r) in s.tick(Instant::now()).unwrap() {
                got.insert(id, r);
            }
            failures.extend(s.take_failures());
            ticks += 1;
            assert!(ticks < 10_000, "chaos burst did not converge");
        }
        (got, failures, ids, s)
    };
    let (clean, none, _, _) = run(None);
    assert!(none.is_empty());
    assert_eq!(clean.len(), 4);
    // Fault on the 4th chunk overall: lands inside the first long
    // prompt's prefill (24 tokens = 6 chunks).
    let (got, failures, ids, s) = run(Some(3));
    assert_eq!(failures.len(), 1, "exactly one session faults");
    let (victim, err) = &failures[0];
    assert!(ids.contains(victim), "the victim is one of the burst sessions");
    assert!(
        matches!(err, RequestError::SessionFault(m) if m.contains("prefill")),
        "got: {err:?}"
    );
    assert!(!got.contains_key(victim));
    for (id, r) in &got {
        assert_eq!(r.tokens, clean[id].tokens, "survivor {id} diverged");
    }
    assert_eq!(s.metrics.session_faults, 1);
    assert_eq!(s.paged_kv().unwrap().sessions(), 0, "victim blocks freed");
}

/// TTFT ordering sanity for the bench: under the lane, a long prompt's
/// first token lands AFTER its prefill chunks complete, and `ttft_ms`
/// covers the lane time (>= queue time, monotone with prompt length in
/// chunk count).
#[test]
fn lane_ttft_accounts_for_chunked_prefill() {
    let core = SimCore::new(4, 42, vec![1, 4]).with_chunked_prefill(4);
    let mut s = Scheduler::new(core, cfg(64)).with_chunked_prefill(arb(4, 1));
    s.submit(vec![1, 7], 60).unwrap();
    let _ = s.tick(Instant::now()).unwrap();
    let id = s.submit((200..240).collect(), 4).unwrap(); // 10 chunks at 1/tick
    let mut first_token_tick = None;
    let mut lane_done_tick = None;
    let mut results = BTreeMap::new();
    for tick in 0..10_000 {
        for (rid, r) in s.tick(Instant::now()).unwrap() {
            results.insert(rid, r);
        }
        if lane_done_tick.is_none() && s.core().prefill_chunks_run >= 10 {
            lane_done_tick = Some(tick);
        }
        if first_token_tick.is_none()
            && s.take_token_events().iter().any(|(i, t)| *i == id && !t.is_empty())
        {
            first_token_tick = Some(tick);
        }
        if results.contains_key(&id) {
            break;
        }
    }
    let (lane_done, first) = (lane_done_tick.unwrap(), first_token_tick.unwrap());
    assert!(
        first >= lane_done,
        "first token (tick {first}) before prefill completed (tick {lane_done})"
    );
    let r = &results[&id];
    assert!(r.ttft_ms >= 0.0 && r.ttft_ms >= r.queue_ms, "ttft excludes lane time");
}
