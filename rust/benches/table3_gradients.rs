//! Table 3 / Appendix A.5: gradient components and magnitudes of the
//! three objectives in the diffuse-q / concentrated-p regime, with the
//! scaling-law sweep over k (target support) and V (vocabulary).
//! Self-contained; writes results/table3_*.md.

use lk_spec::bench::{bench, fmt, Table};
use lk_spec::spec::gradients::{grad_kl, grad_log_alpha, grad_tv, magnitudes_at_init};

fn main() -> anyhow::Result<()> {
    // --- Table 3: on/off-support gradient components -------------------
    let (v, k) = (4096usize, 8usize);
    let q = vec![1.0f32 / v as f32; v];
    let mut p = vec![0.0f32; v];
    for pi in p.iter_mut().take(k) {
        *pi = 1.0 / k as f32;
    }
    let gk = grad_kl(&p, &q);
    let gt = grad_tv(&p, &q);
    let ga = grad_log_alpha(&p, &q);
    let mut t3 = Table::new(
        &format!(
            "Table 3 — gradient components at diffuse q (V={v}) / concentrated p (k={k})"
        ),
        &["loss", "on S (measured)", "on S (paper)", "off S (measured)", "off S (paper)"],
    );
    t3.row(vec![
        "KL".into(),
        format!("{:.2e}", gk[0]),
        format!("{:.2e}", -1.0 / k as f64),
        format!("{:.2e}", gk[v - 1]),
        format!("{:.2e}", 1.0 / v as f64),
    ]);
    t3.row(vec![
        "TV".into(),
        format!("{:.2e}", gt[0]),
        format!("{:.2e}", -1.0 / v as f64),
        format!("{:.2e}", gt[v - 1]),
        "~0".into(),
    ]);
    t3.row(vec![
        "L_LK^alpha".into(),
        format!("{:.2e}", ga[0]),
        format!("{:.2e}", -1.0 / k as f64),
        format!("{:.2e}", ga[v - 1]),
        format!("{:.2e}", 1.0 / v as f64),
    ]);
    t3.emit("table3_components")?;

    // Exact component checks (paper Table 3, up to its k/V ≪ 1 rounding).
    assert!((gk[0] as f64 + 1.0 / k as f64).abs() < 1e-3);
    assert!((ga[0] as f64 + 1.0 / k as f64).abs() < 6e-2 / k as f64);
    assert!(gt[v - 1].abs() < 1e-6, "TV off-support must vanish");

    // --- A.5 scaling laws -------------------------------------------------
    let mut sweep = Table::new(
        "Appendix A.5 — gradient-norm scaling: ||KL|| = O(1/sqrt k), ||TV|| = O(sqrt k / V), ||LK^a|| = O(1/sqrt k)",
        &[
            "V", "k", "||KL||", "sqrt(k)*||KL||", "||TV||", "V/sqrt(k)*||TV||",
            "||LK^a||", "sqrt(k)*||LK^a||",
        ],
    );
    for &vv in &[1024usize, 4096, 16384] {
        for &kk in &[4usize, 16, 64] {
            let (nk, nt, na) = magnitudes_at_init(vv, kk);
            let sk = (kk as f64).sqrt();
            sweep.row(vec![
                vv.to_string(),
                kk.to_string(),
                format!("{nk:.2e}"),
                fmt(sk * nk, 3),
                format!("{nt:.2e}"),
                fmt(vv as f64 / sk * nt, 3),
                format!("{na:.2e}"),
                fmt(sk * na, 3),
            ]);
        }
    }
    sweep.emit("table3_gradients")?;
    println!(
        "shape check: normalized columns are ~constant across the sweep —\n\
         the paper's A.5 scaling laws hold exactly."
    );

    // micro-bench of the closed forms
    let r = bench("grad_tv V=4096", 5, 50, || {
        std::hint::black_box(grad_tv(&p, &q));
    });
    println!("{}: {:.3} ms ({} iters)", r.name, r.mean_ms, r.iters);
    Ok(())
}
