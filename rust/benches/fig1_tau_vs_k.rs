//! Figure 1: τ vs maximum draft length K (1..7) for EAGLE-3 drafts
//! trained with KL / TV / LK^α / LK^λ on the Qwen3-235B analog (moe-l),
//! chat domain, chain sampling at T=1.
//!
//! Reads cached cells; writes results/fig1_tau_vs_k.md with an ASCII
//! rendition of the figure; checks the paper's shape: curves saturate in
//! K, LK curves sit above KL with the gap growing in K, TV far below.

use lk_spec::bench::{fmt, skip, Table};
use lk_spec::config::plan;
use lk_spec::data::grammar::Domain;
use lk_spec::eval::{cached_cell, EvalMode};
use lk_spec::train::RunDirs;

fn main() -> anyhow::Result<()> {
    let dirs = RunDirs::new(std::path::Path::new("runs"));
    let runs = plan::fig1();
    let ks: Vec<usize> = (1..=7).collect();

    let mut series = Vec::new();
    for r in &runs {
        let mut taus = Vec::new();
        for &k in &ks {
            match cached_cell(&dirs, &r.draft, &r.loss.tag, Domain::Chat, EvalMode::T1, k) {
                Some(c) => taus.push(c.tau),
                None => {
                    skip(&format!("fig1 cell {} k={k} missing", r.loss.tag));
                    return Ok(());
                }
            }
        }
        series.push((r.loss.clone(), taus));
    }

    let mut table = Table::new(
        "Figure 1 — τ vs max draft length K (EAGLE-3 @ Qwen3-235B analog, chat, T=1)",
        &["loss", "K=1", "K=2", "K=3", "K=4", "K=5", "K=6", "K=7"],
    );
    for (loss, taus) in &series {
        let mut row = vec![loss.label.clone()];
        row.extend(taus.iter().map(|&t| fmt(t, 3)));
        table.row(row);
    }
    table.emit("fig1_tau_vs_k")?;

    // ASCII figure
    let tmax = series
        .iter()
        .flat_map(|(_, t)| t.iter())
        .fold(1.0f64, |a, &b| a.max(b));
    println!("tau");
    let height = 12;
    for h in (0..=height).rev() {
        let level = 1.0 + (tmax - 1.0) * h as f64 / height as f64;
        let mut line = format!("{level:5.2} |");
        for k in 0..7 {
            for (i, (_, taus)) in series.iter().enumerate() {
                let ch = ["K", "T", "a", "L"][i]; // KL, TV, LK^a, LK^λ
                if (taus[k] - level).abs() <= (tmax - 1.0) / height as f64 / 2.0 {
                    line.push_str(ch);
                } else {
                    line.push(' ');
                }
            }
            line.push_str("  ");
        }
        println!("{line}");
    }
    println!("       K=1    2     3     4     5     6     7   (K=KL T=TV a=LK^a L=LK^λ)");

    // ---- shape checks ------------------------------------------------------
    let find = |tag: &str| {
        series
            .iter()
            .find(|(l, _)| l.tag == tag)
            .map(|(_, t)| t.clone())
            .unwrap()
    };
    let kl = find("kl");
    let tv = find("tv");
    let lkl = find("lkl-eta3");
    let mut ok = true;
    let mut check = |name: &str, cond: bool| {
        println!("  {} {name}", if cond { "PASS" } else { "MISS" });
        ok &= cond;
    };
    check("curves monotone non-decreasing in K (KL)", kl.windows(2).all(|w| w[1] >= w[0] - 0.05));
    check("TV below KL at every K", tv.iter().zip(&kl).all(|(t, k)| t < k));
    check("LK^λ ≥ KL at K=7", lkl[6] >= kl[6] - 1e-9);
    check(
        "LK^λ-vs-KL gap grows with K (paper: divergence at long drafts)",
        (lkl[6] - kl[6]) >= (lkl[0] - kl[0]) - 0.05,
    );
    println!("shape checks {}", if ok { "ALL PASS" } else { "— some missed" });
    Ok(())
}
