//! Table 2: τ for KL vs LK^λ(η=3) across all six target analogs (8B →
//! 685B in the paper; dense-s → mtp-l here), with relative improvement,
//! plus the MTP original/KL-ft/LK-ft rows for the DeepSeek analog.
//!
//! Reads cached cells; writes results/table2_scaling.md; checks §6.2
//! shapes: LK^λ ≥ KL everywhere at T=1, MoE gains ≥ dense gains pattern,
//! MTP fine-tuning ≫ original.

use lk_spec::bench::{fmt, skip, Table};
use lk_spec::config::MTP_ORIGINAL_TAG;
use lk_spec::data::grammar::DOMAINS;
use lk_spec::eval::{cached_cell, EvalMode};
use lk_spec::train::RunDirs;

fn mean3(
    dirs: &RunDirs,
    draft: &str,
    tag: &str,
    mode: EvalMode,
) -> Option<(f64, Vec<f64>)> {
    let mut taus = Vec::new();
    for d in DOMAINS {
        taus.push(cached_cell(dirs, draft, tag, d, mode, 7)?.tau);
    }
    Some((taus.iter().sum::<f64>() / 3.0, taus))
}

fn main() -> anyhow::Result<()> {
    let dirs = RunDirs::new(std::path::Path::new("runs"));
    let rows: Vec<(&str, &str, Vec<&str>)> = vec![
        ("LLaMA-3.1-8B analog", "eagle3@dense-s", vec!["kl", "lkl-eta3"]),
        ("LLaMA-3.3-70B analog", "eagle3@dense-m", vec!["kl", "lkl-eta3"]),
        ("gpt-oss-20b analog", "eagle3@moe-s", vec!["kl", "lkl-eta3"]),
        ("gpt-oss-120b analog", "eagle3@moe-m", vec!["kl", "lkl-eta3"]),
        ("Qwen3-235B analog", "eagle3@moe-l", vec!["kl", "lkl-eta3"]),
        (
            "DeepSeek-V3 analog (MTP)",
            "mtp@mtp-l",
            vec![MTP_ORIGINAL_TAG, "kl", "lkl-eta3"],
        ),
    ];

    let mut table = Table::new(
        "Table 2 — τ across target scales, KL vs LK^λ(η=3) (paper Δ%: +1.6/+0.5/+0.9/+1.8/+1.8/+0.8 at T=0; +3.9/+3.5/+3.8/+7.7/+8.2/+5.6 at T=1)",
        &["target", "loss", "T", "chat", "code", "math", "mean", "Δ% vs KL"],
    );
    let mut gains_t1 = Vec::new();
    let mut missing = false;
    for (label, draft, tags) in &rows {
        for mode in [EvalMode::T0, EvalMode::T1] {
            let kl_mean = mean3(&dirs, draft, "kl", mode).map(|x| x.0);
            for tag in tags {
                let Some((mean, taus)) = mean3(&dirs, draft, tag, mode) else {
                    missing = true;
                    continue;
                };
                let delta = match (*tag, kl_mean) {
                    ("kl", _) | (_, None) => String::new(),
                    (_, Some(klm)) => format!("{:+.1}", (mean / klm - 1.0) * 100.0),
                };
                if *tag == "lkl-eta3" && mode == EvalMode::T1 {
                    if let Some(klm) = kl_mean {
                        gains_t1.push((label.to_string(), (mean / klm - 1.0) * 100.0));
                    }
                }
                table.row(vec![
                    label.to_string(),
                    tag.to_string(),
                    if mode == EvalMode::T0 { "0" } else { "1" }.into(),
                    fmt(taus[0], 3),
                    fmt(taus[1], 3),
                    fmt(taus[2], 3),
                    fmt(mean, 3),
                    delta,
                ]);
            }
        }
    }
    if missing {
        skip("some Table 2 cells missing");
        return Ok(());
    }
    table.emit("table2_scaling")?;

    // ---- §6.2 shape checks ------------------------------------------------
    let mut ok = true;
    for (label, gain) in &gains_t1 {
        let pass = *gain > -0.5; // LK^λ ≥ KL (tolerate tiny noise)
        println!("  {} LK^λ vs KL at T=1 on {label}: {gain:+.1}%", if pass { "PASS" } else { "MISS" });
        ok &= pass;
    }
    // MTP fine-tuning must dominate the original module (the paper's
    // most dramatic row: 3.09 → 4.43/4.68 at T=1).
    let orig = mean3(&dirs, "mtp@mtp-l", MTP_ORIGINAL_TAG, EvalMode::T1).unwrap().0;
    let ft = mean3(&dirs, "mtp@mtp-l", "lkl-eta3", EvalMode::T1).unwrap().0;
    let pass = ft > orig;
    println!(
        "  {} MTP LK-ft ({ft:.2}) > original ({orig:.2})",
        if pass { "PASS" } else { "MISS" }
    );
    ok &= pass;
    println!("shape checks {}", if ok { "ALL PASS" } else { "— some missed" });
    Ok(())
}
