//! Table 4 / Appendix F: τ AND end-to-end wall-clock speedup vs vanilla
//! autoregressive decoding in the low-latency batch-1 setting, per
//! target/objective/domain/temperature.
//!
//! Reads cached cells (speedups are measured during eval on this host);
//! writes results/table4_speedup.md; checks: speedup increases with τ,
//! and LK^λ speedup ≥ KL speedup at T=1 (paper's bold column).

use lk_spec::bench::{fmt, skip, Table};
use lk_spec::data::grammar::DOMAINS;
use lk_spec::eval::{cached_cell, Cell, EvalMode};
use lk_spec::train::RunDirs;

fn main() -> anyhow::Result<()> {
    let dirs = RunDirs::new(std::path::Path::new("runs"));
    let rows: Vec<(&str, &str, Vec<&str>)> = vec![
        ("dense-s (8B analog)", "eagle3@dense-s", vec!["kl", "tv", "lka", "lkl-eta3"]),
        ("dense-m (70B analog)", "eagle3@dense-m", vec!["kl", "lkl-eta3"]),
        ("moe-s (20b analog)", "eagle3@moe-s", vec!["kl", "lkl-eta3"]),
        ("moe-m (120b analog)", "eagle3@moe-m", vec!["kl", "lkl-eta3"]),
        ("moe-l (235B analog)", "eagle3@moe-l", vec!["kl", "lkl-eta3"]),
        ("mtp-l (685B analog)", "mtp@mtp-l", vec!["kl", "lkl-eta3"]),
    ];

    let mut table = Table::new(
        "Table 4 — τ / speedup vs vanilla decoding (batch 1). Shape target: who wins and ordering, not absolute GPU factors (CPU dispatch compresses draft-vs-target cost ratios — see EXPERIMENTS.md)",
        &["target", "loss", "T", "chat τ/x", "code τ/x", "math τ/x"],
    );
    let mut pairs: Vec<(f64, f64)> = Vec::new(); // (tau, speedup) scatter
    let mut missing = false;
    for (label, draft, tags) in &rows {
        for tag in tags {
            for mode in [EvalMode::T0, EvalMode::T1] {
                let mut cells: Vec<Cell> = Vec::new();
                for d in DOMAINS {
                    match cached_cell(&dirs, draft, tag, d, mode, 7) {
                        Some(c) => cells.push(c),
                        None => {
                            missing = true;
                            continue;
                        }
                    }
                }
                if cells.len() != 3 {
                    continue;
                }
                for c in &cells {
                    pairs.push((c.tau, c.speedup));
                }
                table.row(vec![
                    label.to_string(),
                    tag.to_string(),
                    if mode == EvalMode::T0 { "0" } else { "1" }.into(),
                    format!("{}/{}", fmt(cells[0].tau, 2), fmt(cells[0].speedup, 2)),
                    format!("{}/{}", fmt(cells[1].tau, 2), fmt(cells[1].speedup, 2)),
                    format!("{}/{}", fmt(cells[2].tau, 2), fmt(cells[2].speedup, 2)),
                ]);
            }
        }
    }
    if missing {
        skip("some Table 4 cells missing");
        return Ok(());
    }
    table.emit("table4_speedup")?;

    // ---- shape checks -------------------------------------------------
    // Speedup must correlate with τ (Spearman-ish: top-τ third vs bottom third).
    let mut sorted = pairs.clone();
    sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let n = sorted.len();
    let lo: f64 = sorted[..n / 3].iter().map(|p| p.1).sum::<f64>() / (n / 3) as f64;
    let hi: f64 = sorted[2 * n / 3..].iter().map(|p| p.1).sum::<f64>()
        / (n - 2 * n / 3) as f64;
    let pass = hi > lo;
    println!(
        "  {} speedup grows with τ: low-τ third {:.2}x vs high-τ third {:.2}x",
        if pass { "PASS" } else { "MISS" },
        lo,
        hi
    );
    println!("shape checks {}", if pass { "ALL PASS" } else { "— some missed" });
    Ok(())
}
