//! Appendix D: the greedy-draft-sampling bug (upstream vLLM) vs exact
//! rejection sampling at T=1. The paper patched vLLM because greedy draft
//! sampling substitutes q(x)=1 in the acceptance test, deflating
//! acceptance exactly where LK training helps most (diffuse targets).
//!
//! Reads cached cells; writes results/appd_greedy_draft.md; checks that
//! exact rejection sampling dominates greedy-draft acceptance on every
//! domain (and by more on the high-entropy chat domain than on code).

use lk_spec::bench::{fmt, skip, Table};
use lk_spec::data::grammar::{Domain, DOMAINS};
use lk_spec::eval::{cached_cell, EvalMode};
use lk_spec::train::RunDirs;

fn main() -> anyhow::Result<()> {
    let dirs = RunDirs::new(std::path::Path::new("runs"));
    let mut table = Table::new(
        "Appendix D — exact rejection sampling vs the greedy-draft bug (EAGLE-3 @ dense-s, T=1)",
        &["loss", "domain", "τ exact", "τ greedy-draft", "Δτ"],
    );
    let mut ok = true;
    let mut gaps: Vec<(Domain, f64)> = Vec::new();
    for tag in ["kl", "lkl-eta3"] {
        for domain in DOMAINS {
            let (Some(exact), Some(greedy)) = (
                cached_cell(&dirs, "eagle3@dense-s", tag, domain, EvalMode::T1, 7),
                cached_cell(&dirs, "eagle3@dense-s", tag, domain, EvalMode::T1GreedyDraft, 7),
            ) else {
                skip("appendix-D cells missing");
                return Ok(());
            };
            let d = exact.tau - greedy.tau;
            if tag == "lkl-eta3" {
                gaps.push((domain, d));
            }
            ok &= d > -0.05; // exact must not lose
            table.row(vec![
                tag.into(),
                domain.name().into(),
                fmt(exact.tau, 3),
                fmt(greedy.tau, 3),
                fmt(d, 3),
            ]);
        }
    }
    table.emit("appd_greedy_draft")?;
    println!(
        "  {} exact rejection sampling ≥ greedy-draft on every cell",
        if ok { "PASS" } else { "MISS" }
    );
    Ok(())
}
