//! Figure 2: Gaussian-mixture toy — fit a single Gaussian under forward
//! KL / reverse KL / TV, report overlap α (the continuous acceptance
//! rate). Self-contained; writes results/fig2_toy_gaussian.md.

use lk_spec::bench::{bench, fmt, Table};
use lk_spec::spec::overlap::{fit, grid, overlap, Mixture, Objective};

fn main() -> anyhow::Result<()> {
    let target = Mixture::paper_toy();
    let xs = grid(-12.0, 12.0, 2001);

    let mut table = Table::new(
        "Figure 2 — single Gaussian fit to a bimodal mixture (paper: KL 50.2% / revKL 50.8% / TV 60.2%)",
        &["objective", "mu", "sigma", "objective value", "overlap alpha %"],
    );
    let mut alphas = Vec::new();
    for obj in [Objective::ForwardKl, Objective::ReverseKl, Objective::Tv] {
        let (mu, sg, val) = fit(obj, &target, &xs);
        let a = overlap(&target, mu, sg, &xs);
        alphas.push((obj, a));
        table.row(vec![
            obj.name().to_string(),
            fmt(mu, 2),
            fmt(sg, 2),
            fmt(val, 4),
            fmt(a * 100.0, 1),
        ]);
    }
    table.emit("fig2_toy_gaussian")?;
    let a_tv = alphas[2].1;
    assert!(
        a_tv > alphas[0].1 && a_tv > alphas[1].1,
        "paper shape violated: TV must maximize overlap"
    );
    println!("shape check OK: TV maximizes overlap (paper Fig. 2)");

    // micro-bench: objective evaluation throughput (hot loop of the fit)
    let r = bench("tv objective eval", 3, 30, || {
        std::hint::black_box(lk_spec::spec::overlap::objective_value(
            Objective::Tv,
            &target,
            0.3,
            2.0,
            &xs,
        ));
    });
    println!("{}: {:.3} ms/iter (p95 {:.3})", r.name, r.mean_ms, r.p95_ms);
    Ok(())
}
