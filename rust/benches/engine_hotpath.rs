//! §Perf bench: engine hot-path decomposition. Measures per-executable
//! dispatch cost, the engine's non-XLA overhead fraction, and end-to-end
//! round latency — the numbers the EXPERIMENTS.md §Perf log tracks.
//!
//! Needs artifacts + a dense-s target/draft checkpoint (kl).

use std::path::Path;
use std::time::Instant;

use lk_spec::bench::{bench, skip, JsonRows, Table};
use lk_spec::data::corpus::Corpus;
use lk_spec::data::grammar::Domain;
use lk_spec::eval::{EvalMode, EvalSettings};
use lk_spec::runtime::Runtime;
use lk_spec::server::batcher::BatcherConfig;
use lk_spec::server::kv::{PagedKv, PagedKvConfig};
use lk_spec::server::metrics::{
    device_bytes_per_round, host_draft_bytes_per_round, host_verify_bytes_per_round,
    migration_host_kv_bytes_device, migration_host_kv_bytes_host_repack,
    recurrent_tree_device_bytes_per_round, recurrent_tree_host_bytes_per_round,
    tree_device_bytes_per_round, tree_host_bytes_per_round,
};
use lk_spec::server::{
    AdaptConfig, DownshiftConfig, FaultConfig, FaultPlan, HttpOpts, HttpServer, Router,
    RouterConfig, Scheduler, SimCore,
};
use lk_spec::spec::adaptive::{
    ControllerCfg, CostModel, PrefillArbiter, PrefillArbiterCfg, SpecController,
};
use lk_spec::tensor::HostTensor;
use lk_spec::train::RunDirs;
use lk_spec::util::Json;

/// Host-side scheduler bookkeeping cost (slot allocation, join/leave,
/// metrics) measured against the PJRT-free SimCore — isolates the
/// continuous-batching overhead the engine adds per round. Always runs,
/// even without artifacts.
fn bench_scheduler_overhead() -> anyhow::Result<()> {
    let mut table = Table::new(
        "Scheduler bookkeeping overhead (SimCore, buckets {1,4})",
        &["scenario", "mean ms", "p95 ms", "p99 ms"],
    );
    for (name, n_requests, max_new) in [
        ("drain 32 × 16tok", 32usize, 16usize),
        ("drain 64 × 32tok", 64, 32),
        ("churn 128 × 8tok", 128, 8),
    ] {
        let r = bench(name, 2, 20, || {
            let cfg = BatcherConfig {
                buckets: vec![1, 4],
                max_wait: std::time::Duration::ZERO,
                queue_cap: 4096,
            };
            let mut sched = Scheduler::new(SimCore::new(4, 0xBE5C, vec![1, 4]), cfg);
            let mut served = 0usize;
            // Prime a full bucket, then trickle the rest so the
            // join-mid-flight path (not just group formation) is hot.
            let mut submitted = 0usize;
            while submitted < 4.min(n_requests) {
                sched
                    .submit(vec![1 + submitted as i32, 2, 3], max_new)
                    .unwrap();
                submitted += 1;
            }
            while served < n_requests {
                if submitted < n_requests {
                    sched
                        .submit(vec![1 + submitted as i32, 2, 3], max_new)
                        .unwrap();
                    submitted += 1;
                }
                served += sched.tick(Instant::now()).unwrap().len();
            }
            assert!(sched.is_idle());
        });
        table.row(vec![
            name.to_string(),
            format!("{:.3}", r.mean_ms),
            format!("{:.3}", r.p95_ms),
            format!("{:.3}", r.p99_ms),
        ]);
    }
    table.emit("scheduler_overhead")?;
    Ok(())
}

/// §Paged-KV bench: effective concurrent capacity of the block pool on
/// a shared-system-prompt serving mix, dense accounting (prefix cache
/// off — every session pays its full prompt) vs the radix prefix cache,
/// at equal block budgets. Pure block-accounting arithmetic on
/// `PagedKv` — PJRT-free, always runs.
///
/// Mix: every request is a 32-token shared system prompt plus a 4-token
/// distinct user suffix, max_new 12 (block size 16 → 3 blocks/session
/// dense, 1 private block/session once the prefix is warm). Capacity =
/// admits until the pool sheds, with no releases in between — i.e. how
/// many sessions can be resident at once.
fn bench_paged_kv_capacity(json: &mut JsonRows) -> anyhow::Result<()> {
    const BLOCK_SIZE: usize = 16;
    const MAX_NEW: usize = 12;
    let sys_prompt: Vec<i32> = (0..32).collect();
    let capacity = |prefix_cache: bool, budget: usize| -> (usize, f64) {
        let mut kv = PagedKv::new(PagedKvConfig {
            block_size: BLOCK_SIZE,
            total_blocks: budget,
            prefix_cache,
        });
        let mut admitted = 0usize;
        loop {
            let mut prompt = sys_prompt.clone();
            prompt.extend([1000 + admitted as i32, 2, 3, 4]);
            if kv.admit(admitted as u64, &prompt, MAX_NEW).is_err() {
                break;
            }
            admitted += 1;
        }
        (admitted, kv.prefix_hit_rate())
    };

    let mut table = Table::new(
        "Paged-KV effective capacity (shared-system-prompt mix, block size 16)",
        &["block budget", "dense", "paged", "ratio", "prefix hit rate"],
    );
    for budget in [16usize, 24, 32, 64] {
        let (dense, _) = capacity(false, budget);
        let (paged, hit_rate) = capacity(true, budget);
        let ratio = paged as f64 / dense.max(1) as f64;
        table.row(vec![
            budget.to_string(),
            dense.to_string(),
            paged.to_string(),
            format!("{ratio:.2}x"),
            format!("{hit_rate:.3}"),
        ]);
        json.push(vec![
            ("bench", Json::Str("paged_kv_capacity".into())),
            ("config", Json::Str(format!("shared-sys-prompt budget={budget}"))),
            ("block_budget", Json::Num(budget as f64)),
            ("capacity_dense", Json::Num(dense as f64)),
            ("capacity_paged", Json::Num(paged as f64)),
            ("capacity_ratio", Json::Num(ratio)),
            ("prefix_hit_rate", Json::Num(hit_rate)),
        ]);
        // ISSUE-6 acceptance: the prefix cache must at least double the
        // resident-session capacity at equal block budget on this mix.
        anyhow::ensure!(
            ratio >= 2.0,
            "paged capacity {paged} < 2x dense {dense} at budget {budget}"
        );
    }
    table.emit("paged_kv_capacity")?;
    Ok(())
}

/// §Migration transfer: closed-form host KV bytes for one cross-bucket
/// move at the manifest's target dims (L=4, H=4, Smax=88, Dh=24), host
/// repack (the pre-paged fallback) vs the `kv_gather_rows_b{s}x{d}`
/// device path. Analytic twin of the live
/// `EngineMetrics::host_kv_bytes_per_migration()` counter, which the
/// integration suite pins to 0.0 on the device path.
fn bench_kv_migration_analytic(json: &mut JsonRows) -> anyhow::Result<()> {
    let (n_layers, heads, max_seq, head_dim) = (4usize, 4usize, 88usize, 24usize);
    let mut table = Table::new(
        "Cross-bucket KV migration — host bytes per move (analytic, manifest dims)",
        &["move", "host repack B", "device gather B"],
    );
    for (b_src, b_dst, with_draft, name) in [
        (4usize, 1usize, true, "downshift 4->1 (+draft kv)"),
        (1, 4, true, "upshift 1->4 (+draft kv)"),
        (4, 1, false, "downshift 4->1 (target only)"),
    ] {
        let host = migration_host_kv_bytes_host_repack(
            n_layers, b_src, b_dst, heads, max_seq, head_dim, with_draft,
        );
        let dev = migration_host_kv_bytes_device();
        table.row(vec![name.to_string(), host.to_string(), dev.to_string()]);
        json.push(vec![
            ("bench", Json::Str("kv_migration_analytic".into())),
            ("config", Json::Str(name.into())),
            ("host_kv_bytes_host_repack", Json::Num(host as f64)),
            ("host_kv_bytes_device", Json::Num(dev as f64)),
        ]);
    }
    table.emit("kv_migration")?;
    Ok(())
}

/// One SimCore serving run for the controller bench; returns the cost
/// ledger the table and BENCH_engine.json rows are built from.
struct ControllerRun {
    rounds: u64,
    round_k_sum: u64,
    padded_row_rounds: u64,
    downshifts: u64,
    tokens: u64,
    accepted: u64,
    live_row_rounds: u64,
    secs: f64,
}

impl ControllerRun {
    fn rounds_per_token(&self) -> f64 {
        self.rounds as f64 / self.tokens.max(1) as f64
    }

    /// Simulated round cost: one verify-unit per round plus the draft
    /// chain (group-level — drafting is batched across rows).
    fn cost_per_token(&self, draft_cost: f64) -> f64 {
        (self.rounds as f64 + draft_cost * self.round_k_sum as f64) / self.tokens.max(1) as f64
    }

    fn accepted_len_mean(&self) -> f64 {
        self.accepted as f64 / self.live_row_rounds.max(1) as f64
    }
}

/// §Controller bench: adaptive K + long-tail downshift against the
/// fixed-K grid on a low-α long-tail mix (SimCore — always runs).
///
/// Workload: four high-α (0.9) short sessions fill the b=4 bucket; a
/// low-α (0.15) long request queues behind them, joins mid-flight and
/// ends as a 1-row long tail. No single fixed K serves both phases:
/// deep chains pay wasted drafts in the tail, short chains slow the
/// high-α phase — and without downshift the tail burns 3 padding rows
/// per round. The controller runs K≈max through the high-α phase,
/// collapses the chain when the tail's acceptance shows up, and the
/// scheduler migrates the group to the b=1 bucket.
fn bench_speculation_controller(json: &mut JsonRows) -> anyhow::Result<()> {
    const DRAFT_COST: f64 = 0.5;
    const K_MAX: usize = 7;
    let profiles = vec![
        vec![0.9; K_MAX], // ids 0..3: the short high-α burst
        vec![0.9; K_MAX],
        vec![0.9; K_MAX],
        vec![0.9; K_MAX],
        vec![0.15; K_MAX], // id 4: the low-α long tail
    ];
    let run = |fixed_k: Option<usize>, downshift: bool| -> anyhow::Result<ControllerRun> {
        let mut core =
            SimCore::new(fixed_k.unwrap_or(K_MAX), 0xADA7, vec![1, 4]).with_alpha(profiles.clone());
        if fixed_k.is_none() {
            core = core.with_controller(SpecController::new(ControllerCfg {
                k_max: K_MAX,
                halflife: 16.0,
                cost: CostModel::chained(DRAFT_COST),
                ..Default::default()
            }));
        }
        let cfg = BatcherConfig {
            buckets: vec![1, 4],
            max_wait: std::time::Duration::ZERO,
            queue_cap: 64,
        };
        let ds = DownshiftConfig {
            enabled: downshift,
            after_rounds: 4,
        };
        let mut sched = Scheduler::with_downshift(core, cfg, ds);
        for i in 0..4 {
            sched.submit(vec![i + 1, 3], 48).map_err(|_| anyhow::anyhow!("queue full"))?;
        }
        sched.submit(vec![9, 9], 96).map_err(|_| anyhow::anyhow!("queue full"))?;
        let t0 = Instant::now();
        let (mut tokens, mut accepted) = (0u64, 0u64);
        let mut served = 0usize;
        let mut ticks = 0usize;
        while served < 5 {
            for (_, r) in sched.tick(Instant::now())? {
                tokens += r.tokens.len() as u64;
                accepted += r.stats.accepted.iter().sum::<u64>();
                served += 1;
            }
            ticks += 1;
            anyhow::ensure!(ticks < 100_000, "controller bench did not converge");
        }
        Ok(ControllerRun {
            rounds: sched.metrics.rounds,
            round_k_sum: sched.core().round_k_sum,
            padded_row_rounds: sched.metrics.padded_row_rounds,
            downshifts: sched.metrics.downshifts,
            tokens,
            accepted,
            live_row_rounds: sched.metrics.live_row_rounds,
            secs: t0.elapsed().as_secs_f64(),
        })
    };

    let mut table = Table::new(
        "Speculation controller vs fixed K (SimCore low-α long-tail mix)",
        &[
            "config",
            "rounds",
            "rounds/tok",
            "cost/tok",
            "padded row-rounds",
            "downshifts",
            "acc len",
        ],
    );
    let mut emit = |name: &str, r: &ControllerRun, json: &mut JsonRows| {
        table.row(vec![
            name.to_string(),
            r.rounds.to_string(),
            format!("{:.4}", r.rounds_per_token()),
            format!("{:.4}", r.cost_per_token(DRAFT_COST)),
            r.padded_row_rounds.to_string(),
            r.downshifts.to_string(),
            format!("{:.2}", r.accepted_len_mean()),
        ]);
        json.push(vec![
            ("bench", Json::Str("speculation_controller".into())),
            ("config", Json::Str(name.into())),
            ("tok_s", Json::Num(r.tokens as f64 / r.secs.max(1e-9))),
            ("tokens", Json::Num(r.tokens as f64)),
            ("rounds", Json::Num(r.rounds as f64)),
            ("rounds_per_token", Json::Num(r.rounds_per_token())),
            ("sim_cost_per_token", Json::Num(r.cost_per_token(DRAFT_COST))),
            ("padded_row_rounds", Json::Num(r.padded_row_rounds as f64)),
            ("downshifts", Json::Num(r.downshifts as f64)),
            ("accepted_len_mean", Json::Num(r.accepted_len_mean())),
            ("bytes_to_host", Json::Num(0.0)), // SimCore: no transfers
        ]);
    };

    let mut best_fixed: Option<(usize, ControllerRun)> = None;
    for k in 1..=K_MAX {
        let r = run(Some(k), false)?; // fixed K, no downshift: the old behavior
        emit(&format!("fixed k={k}"), &r, json);
        let better = match best_fixed.as_ref() {
            Some((_, b)) => r.cost_per_token(DRAFT_COST) < b.cost_per_token(DRAFT_COST),
            None => true,
        };
        if better {
            best_fixed = Some((k, r));
        }
    }
    let adaptive = run(None, true)?;
    emit("adaptive + downshift", &adaptive, json);
    table.emit("speculation_controller")?;

    let (bk, best) = best_fixed.expect("fixed grid ran");
    println!(
        "best fixed K by simulated cost: k={bk} ({:.4} cost/tok, {:.4} rounds/tok, \
         {} padded row-rounds)\nadaptive + downshift:          \
         {:.4} cost/tok, {:.4} rounds/tok, {} padded row-rounds{}",
        best.cost_per_token(DRAFT_COST),
        best.rounds_per_token(),
        best.padded_row_rounds,
        adaptive.cost_per_token(DRAFT_COST),
        adaptive.rounds_per_token(),
        adaptive.padded_row_rounds,
        if adaptive.rounds_per_token() < best.rounds_per_token()
            && adaptive.padded_row_rounds < best.padded_row_rounds
        {
            "  << beats the best fixed K on both"
        } else {
            ""
        },
    );
    Ok(())
}

/// §Chaos smoke (DESIGN.md §9): one serving run per fault class on the
/// SimCore + FaultPlan harness — sessions lost, rounds, retry counts,
/// and (after an engine-fatal) rounds until a fresh probe request
/// completes against the reset scheduler. PJRT-free, always runs; the
/// ensure! guards turn the containment contract into a CI tripwire:
/// transient loses ZERO sessions, session-fatal loses exactly ONE.
fn bench_chaos_smoke(json: &mut JsonRows) -> anyhow::Result<()> {
    const SESSIONS: usize = 8;
    const MAX_NEW: usize = 16;
    struct ChaosRun {
        lost: usize,
        faults_injected: u64,
        rounds: u64,
        transient_retries: u64,
        rounds_to_recover: u64,
    }
    let run = |plan: FaultPlan| -> anyhow::Result<ChaosRun> {
        let cfg = BatcherConfig {
            buckets: vec![1, 4],
            max_wait: std::time::Duration::ZERO,
            queue_cap: 64,
        };
        let mut sched = Scheduler::new(
            SimCore::new(4, 0xC4A0, vec![1, 4]).with_fault_plan(plan),
            cfg,
        )
        .with_fault_config(FaultConfig {
            transient_retries: 3,
            backoff: std::time::Duration::ZERO,
        })
        .with_paged_kv(PagedKvConfig {
            block_size: 16,
            total_blocks: 64,
            prefix_cache: true,
        });
        for i in 0..SESSIONS {
            sched
                .submit(vec![i as i32 + 1, 2], MAX_NEW)
                .map_err(|e| anyhow::anyhow!("chaos submit: {e}"))?;
        }
        let (mut served, mut lost) = (0usize, 0usize);
        let mut rounds_to_recover = 0u64;
        let mut ticks = 0usize;
        while served + lost < SESSIONS {
            match sched.tick(Instant::now()) {
                Ok(done) => {
                    served += done.len();
                    lost += sched.take_failures().len();
                }
                Err(_) => {
                    // Engine-fatal: everything in flight or queued is
                    // lost; reset rebuilds the paged pool, then a probe
                    // request pins the recovery claim.
                    lost += sched.in_flight() + sched.pending();
                    sched.reset();
                    sched
                        .submit(vec![42, 2], 4)
                        .map_err(|e| anyhow::anyhow!("probe submit: {e}"))?;
                    loop {
                        let done = sched.tick(Instant::now())?;
                        rounds_to_recover += 1;
                        if !done.is_empty() {
                            break;
                        }
                        anyhow::ensure!(
                            rounds_to_recover < 1000,
                            "probe did not complete after reset"
                        );
                    }
                }
            }
            ticks += 1;
            anyhow::ensure!(ticks < 100_000, "chaos run did not converge");
        }
        Ok(ChaosRun {
            lost,
            faults_injected: sched.core().faults_injected,
            rounds: sched.metrics.rounds,
            transient_retries: sched.metrics.transient_retries,
            rounds_to_recover,
        })
    };

    let mut table = Table::new(
        "Chaos smoke — fault containment per class (SimCore + FaultPlan, 8 sessions)",
        &["fault class", "lost", "injected", "rounds", "retries", "rounds to recover"],
    );
    for (name, plan) in [
        ("none", FaultPlan::default()),
        ("transient", FaultPlan::default().transient_at(2, 2)),
        ("session_fatal", FaultPlan::default().session_fatal_at(2, 1)),
        ("engine_fatal", FaultPlan::default().engine_fatal_at(2)),
    ] {
        let r = run(plan)?;
        table.row(vec![
            name.to_string(),
            r.lost.to_string(),
            r.faults_injected.to_string(),
            r.rounds.to_string(),
            r.transient_retries.to_string(),
            r.rounds_to_recover.to_string(),
        ]);
        json.push(vec![
            ("bench", Json::Str("chaos_smoke".into())),
            ("config", Json::Str(format!("{name} sessions={SESSIONS}"))),
            ("sessions", Json::Num(SESSIONS as f64)),
            ("sessions_lost", Json::Num(r.lost as f64)),
            ("faults_injected", Json::Num(r.faults_injected as f64)),
            ("rounds", Json::Num(r.rounds as f64)),
            ("transient_retries", Json::Num(r.transient_retries as f64)),
            ("rounds_to_recover", Json::Num(r.rounds_to_recover as f64)),
        ]);
        // The containment contract as a tripwire, not just a report.
        match name {
            "none" | "transient" => anyhow::ensure!(
                r.lost == 0,
                "{name}: {} sessions lost, contract says zero",
                r.lost
            ),
            "session_fatal" => anyhow::ensure!(
                r.lost == 1,
                "session_fatal: {} sessions lost, contract says exactly one",
                r.lost
            ),
            _ => anyhow::ensure!(
                r.rounds_to_recover >= 1,
                "engine_fatal: recovery probe never ran"
            ),
        }
    }
    table.emit("chaos_smoke")?;
    Ok(())
}

/// §Adaptation drift (DESIGN.md §12): serve a domain-shifted SimCore
/// mix — half the sessions hit an acceptance profile the draft handles
/// well (~0.8), half a shifted one it handles badly (~0.25) — with the
/// online-adaptation loop attached (builtin sim trainer, hot-swap at
/// round boundaries). Reports the empirical acceptance over the replay
/// ring before the last fine-tune vs over the window after the last
/// committed swap. PJRT-free, always runs; the ensure! turns the
/// ISSUE-10 acceptance criterion — fine-tuning on harvested transcripts
/// strictly improves alpha_hat — into a CI tripwire.
fn bench_adaptation_drift(json: &mut JsonRows) -> anyhow::Result<()> {
    const SESSIONS: usize = 8;
    const MAX_NEW: usize = 48;
    let out_dir = std::env::temp_dir().join(format!(
        "lkspec-bench-adapt-{}",
        std::process::id()
    ));
    let cfg = BatcherConfig {
        buckets: vec![1, 4],
        max_wait: std::time::Duration::ZERO,
        queue_cap: 64,
    };
    let mut sched = Scheduler::new(
        // Domain-shifted mix: request id keys the profile, so the two
        // streams interleave inside every decode group.
        SimCore::new(4, 0xADA7, vec![1, 4])
            .with_alpha(vec![vec![0.8; 4], vec![0.25; 4]]),
        cfg,
    )
    .with_adaptation(AdaptConfig {
        interval_rounds: 4,
        min_records: 24,
        out_dir: out_dir.clone(),
        ..AdaptConfig::default()
    });
    for i in 0..SESSIONS {
        sched
            .submit(vec![i as i32 + 1, 2, 3], MAX_NEW)
            .map_err(|e| anyhow::anyhow!("adapt submit: {e}"))?;
    }
    let mut served = 0usize;
    let mut ticks = 0usize;
    while served < SESSIONS {
        served += sched.tick(Instant::now())?.len();
        ticks += 1;
        anyhow::ensure!(ticks < 100_000, "adaptation run did not converge");
    }
    // Let an in-flight fine-tune resolve; idle ticks still poll the
    // trainer and commit the swap at the (empty) round boundary.
    while sched.adapt().map(|d| d.trainer_running()).unwrap_or(false) {
        sched.tick(Instant::now())?;
        ticks += 1;
        anyhow::ensure!(ticks < 110_000, "trainer did not resolve");
    }
    let rounds = sched.metrics.rounds;
    let m = sched.adapt().expect("adaptation attached").metrics.clone();
    let _ = std::fs::remove_dir_all(&out_dir);

    let mut table = Table::new(
        "Adaptation drift — harvested fine-tune on a domain-shifted mix (SimCore, 8 sessions)",
        &["sessions", "rounds", "harvested", "swaps", "runs", "alpha pre", "alpha post"],
    );
    table.row(vec![
        SESSIONS.to_string(),
        rounds.to_string(),
        m.records_harvested_total.to_string(),
        m.swaps_total.to_string(),
        m.trainer_runs_total.to_string(),
        format!("{:.3}", m.alpha_hat_pre),
        format!("{:.3}", m.alpha_hat_post),
    ]);
    json.push(vec![
        ("bench", Json::Str("adaptation_drift".into())),
        ("config", Json::Str(format!(
            "shifted-mix sessions={SESSIONS} interval=4 trainer=sim"
        ))),
        ("sessions", Json::Num(SESSIONS as f64)),
        ("rounds", Json::Num(rounds as f64)),
        ("records_harvested", Json::Num(m.records_harvested_total as f64)),
        ("swaps", Json::Num(m.swaps_total as f64)),
        ("trainer_runs", Json::Num(m.trainer_runs_total as f64)),
        ("alpha_hat_pre", Json::Num(m.alpha_hat_pre)),
        ("alpha_hat_post", Json::Num(m.alpha_hat_post)),
        ("alpha_gain", Json::Num(m.alpha_hat_post - m.alpha_hat_pre)),
    ]);
    anyhow::ensure!(
        m.swaps_total >= 1 && m.records_harvested_total > 0,
        "adaptation loop never swapped ({} swaps, {} records)",
        m.swaps_total,
        m.records_harvested_total
    );
    anyhow::ensure!(
        m.alpha_hat_post > m.alpha_hat_pre,
        "fine-tune did not improve acceptance: pre {:.3} post {:.3}",
        m.alpha_hat_pre,
        m.alpha_hat_post
    );
    table.emit("adaptation_drift")?;
    Ok(())
}

/// §Chunked-prefill interference bench (DESIGN.md §11): TTFT and decode
/// cadence on a bursty long-prompt mix — whole-prompt joins vs the
/// chunked lane, cold vs a warm radix prefix — on SimCore under a
/// virtual cost-unit clock, so the numbers are deterministic and
/// PJRT-free (always runs).
///
/// The clock prices work in verify-call units from the SAME cost model
/// the arbiter budgets with: each decode round costs
/// `CostModel::round_cost(k)`, whole-prompt prefill costs
/// `prompt_len / verify_t` at admission, and a lane chunk costs
/// `chunk / verify_t` on the tick it executes. Workload: a resident
/// keeper decodes throughout; every 3 ticks a wave lands — one 48-token
/// long prompt plus two interactive 4-token shorts. Whole-prompt joins
/// serialize the long's full prefill into the join tick (the decode-gap
/// spike every short in that wave inherits); the lane amortizes it at
/// ≤ 2 chunks/tick. The ensure! guards are the ISSUE-9 acceptance
/// tripwire: the lane must beat whole-prompt p99 short-request TTFT and
/// p99 decode gap cold, and a warm prefix must cut the lane's own
/// long-prompt TTFT (cached chunks skip COMPUTE, not just capacity).
/// The long prompt's own cold TTFT is the documented trade (amortized
/// across rounds, so later than a monolithic join) — reported, not
/// guarded.
fn bench_prefill_interference(json: &mut JsonRows) -> anyhow::Result<()> {
    const CHUNK: usize = 4; // SimCore chunk length (tokens)
    const CAP: usize = 2; // arbiter max chunks per round
    const VERIFY_T: f64 = 8.0; // tokens per verify-equivalent
    const LONG: usize = 48;
    const WAVES: usize = 8;

    #[derive(Clone, Copy, PartialEq)]
    enum Class {
        Keeper,
        Prewarm,
        Long,
        Short,
    }
    struct Req {
        id: u64,
        class: Class,
        submitted: f64,
        len: usize,
        ttft: Option<f64>,
        done: bool,
    }
    struct LaneStats {
        short_p50: f64,
        short_p99: f64,
        long_p50: f64,
        long_p99: f64,
        gap_p50: f64,
        gap_p99: f64,
        chunks: u64,
        saved: u64,
    }
    fn pctl(xs: &[f64], q: f64) -> f64 {
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        v[((v.len() - 1) as f64 * q).round() as usize]
    }

    let cost = CostModel::chained(0.25);
    let rc = cost.round_cost(4);
    let cc = CHUNK as f64 / VERIFY_T;
    let shared: Vec<i32> = (9000..9000 + (LONG as i32 - 4)).collect();

    let run = |chunked: bool, warm: bool| -> anyhow::Result<LaneStats> {
        let mut core = SimCore::new(4, 0x1F17, vec![1, 8]);
        if chunked {
            core = core.with_chunked_prefill(CHUNK);
        }
        let mut s = Scheduler::new(
            core,
            BatcherConfig {
                buckets: vec![1, 8],
                max_wait: std::time::Duration::ZERO,
                queue_cap: 256,
            },
        )
        .with_paged_kv(PagedKvConfig {
            block_size: CHUNK,
            total_blocks: 256,
            prefix_cache: warm,
        });
        if chunked {
            s = s.with_chunked_prefill(PrefillArbiter::new(PrefillArbiterCfg {
                max_chunks_per_round: CAP,
                ..PrefillArbiterCfg::for_chunk(CHUNK, VERIFY_T as usize, cost, 4)
            }));
        }
        let long_prompt = |w: usize| -> Vec<i32> {
            if warm {
                let mut p = shared.clone();
                p.extend([100 + w as i32, 2, 3, 4]);
                p
            } else {
                let base = 1000 + 100 * w as i32;
                (base..base + LONG as i32).collect()
            }
        };

        let mut reqs: Vec<Req> = Vec::new();
        let mut t = 0.0f64;
        let mut gaps: Vec<f64> = Vec::new();
        let mut cursor = 0usize; // admission cursor (FIFO ⇒ submission order)
        let mut wave = 0usize;
        let mut start: Option<usize> = None; // tick the first wave landed
        let sub = |s: &mut Scheduler<SimCore>,
                   reqs: &mut Vec<Req>,
                   prompt: Vec<i32>,
                   max_new: usize,
                   class: Class,
                   t: f64| {
            let len = prompt.len();
            let id = s.submit(prompt, max_new).expect("interference submit");
            reqs.push(Req { id, class, submitted: t, len, ttft: None, done: false });
        };

        sub(&mut s, &mut reqs, vec![1, 7], 400, Class::Keeper, t);
        if warm {
            // Warm the radix cache: one shared-prefix long rides the
            // keeper's bootstrap; its blocks stay cached after release.
            sub(&mut s, &mut reqs, long_prompt(99), 4, Class::Prewarm, t);
        }
        for n in 0..5000usize {
            // Waves gate on the prewarm finishing so every measured
            // long sees the warm prefix.
            let ready = reqs
                .iter()
                .all(|r| r.class != Class::Prewarm || r.done);
            if n >= 1 && ready && wave < WAVES && start.map_or(true, |s0| (n - s0) % 3 == 0) {
                start.get_or_insert(n);
                sub(&mut s, &mut reqs, long_prompt(wave), 4, Class::Long, t);
                for i in 0..2 {
                    let p = vec![5000 + 10 * (2 * wave + i) as i32, 1, 2, 3];
                    sub(&mut s, &mut reqs, p, 3, Class::Short, t);
                }
                wave += 1;
            }
            let adm0 = s.metrics.sessions_admitted;
            let rounds0 = s.core().rounds_run;
            let chunks0 = s.core().prefill_chunks_run;
            let finished = s.tick(Instant::now())?;
            let rounds_d = s.core().rounds_run - rounds0;
            let chunks_d = s.core().prefill_chunks_run - chunks0;
            let mut cost_u = rounds_d as f64 * rc + chunks_d as f64 * cc;
            // Admission-time prefill charges: whole-prompt joins (and
            // bootstraps) pay the full prompt up front; lane entries pay
            // per chunk above instead.
            let adm = (s.metrics.sessions_admitted - adm0) as usize;
            for r in &reqs[cursor..cursor + adm] {
                if !chunked || r.class != Class::Long {
                    cost_u += r.len as f64 / VERIFY_T;
                }
            }
            cursor += adm;
            t += cost_u;
            if start.is_some() && rounds_d > 0 {
                gaps.push(cost_u);
            }
            for (id, toks) in s.take_token_events() {
                if toks.is_empty() {
                    continue;
                }
                if let Some(r) = reqs.iter_mut().find(|r| r.id == id) {
                    r.ttft.get_or_insert(t - r.submitted);
                }
            }
            for (id, _) in finished {
                if let Some(r) = reqs.iter_mut().find(|r| r.id == id) {
                    r.done = true;
                }
            }
            let failures = s.take_failures();
            anyhow::ensure!(failures.is_empty(), "interference run lost sessions");
            if wave == WAVES
                && reqs.iter().all(|r| matches!(r.class, Class::Keeper) || r.done)
            {
                break;
            }
            anyhow::ensure!(n < 4999, "interference run did not converge");
        }
        let collect = |class: Class| -> Vec<f64> {
            reqs.iter()
                .filter(|r| r.class == class)
                .map(|r| r.ttft.expect("finished request missing ttft"))
                .collect()
        };
        let shorts = collect(Class::Short);
        let longs = collect(Class::Long);
        Ok(LaneStats {
            short_p50: pctl(&shorts, 0.5),
            short_p99: pctl(&shorts, 0.99),
            long_p50: pctl(&longs, 0.5),
            long_p99: pctl(&longs, 0.99),
            gap_p50: pctl(&gaps, 0.5),
            gap_p99: pctl(&gaps, 0.99),
            chunks: s.core().prefill_chunks_run,
            saved: s.metrics.prefill_tokens_saved,
        })
    };

    let mut table = Table::new(
        "Chunked-prefill interference — TTFT + decode gap in verify-units \
         (SimCore, 48-tok longs + 4-tok shorts, chunk 4, budget 2)",
        &[
            "config",
            "short ttft p50/p99",
            "long ttft p50/p99",
            "decode gap p50/p99",
            "chunks",
            "saved tok",
        ],
    );
    let mut stats: Vec<(&str, LaneStats)> = Vec::new();
    for (name, chunked, warm) in [
        ("whole cold", false, false),
        ("chunked cold", true, false),
        ("whole warm", false, true),
        ("chunked warm", true, true),
    ] {
        let r = run(chunked, warm)?;
        table.row(vec![
            name.to_string(),
            format!("{:.2} / {:.2}", r.short_p50, r.short_p99),
            format!("{:.2} / {:.2}", r.long_p50, r.long_p99),
            format!("{:.2} / {:.2}", r.gap_p50, r.gap_p99),
            r.chunks.to_string(),
            r.saved.to_string(),
        ]);
        json.push(vec![
            ("bench", Json::Str("prefill_interference".into())),
            ("config", Json::Str(name.into())),
            ("short_ttft_p50", Json::Num(r.short_p50)),
            ("short_ttft_p99", Json::Num(r.short_p99)),
            ("long_ttft_p50", Json::Num(r.long_p50)),
            ("long_ttft_p99", Json::Num(r.long_p99)),
            ("decode_gap_p50", Json::Num(r.gap_p50)),
            ("decode_gap_p99", Json::Num(r.gap_p99)),
            ("prefill_chunks", Json::Num(r.chunks as f64)),
            ("prefill_tokens_saved", Json::Num(r.saved as f64)),
        ]);
        stats.push((name, r));
    }
    let get = |name: &str| &stats.iter().find(|(n, _)| *n == name).unwrap().1;
    let (wc, cc_run) = (get("whole cold"), get("chunked cold"));
    let cw = get("chunked warm");
    // ISSUE-9 acceptance: the lane must move the p99s, not just the
    // means — interactive TTFT and decode cadence both.
    anyhow::ensure!(
        cc_run.short_p99 < wc.short_p99,
        "chunked lane did not improve p99 short TTFT ({:.2} vs {:.2})",
        cc_run.short_p99,
        wc.short_p99
    );
    anyhow::ensure!(
        cc_run.gap_p99 < wc.gap_p99,
        "chunked lane did not improve p99 decode gap ({:.2} vs {:.2})",
        cc_run.gap_p99,
        wc.gap_p99
    );
    anyhow::ensure!(
        cw.long_p50 < cc_run.long_p50 && cw.saved > 0,
        "warm prefix did not cut lane long-prompt TTFT ({:.2} vs {:.2}, saved {})",
        cw.long_p50,
        cc_run.long_p50,
        cw.saved
    );
    table.emit("prefill_interference")?;
    Ok(())
}

/// §HTTP edge bench: per-token SSE streaming latency through the full
/// serving stack (accept thread → parser → router → scheduler →
/// SimCore) over real loopback TCP. Timestamps are CLIENT-side, one
/// per `event: token` frame — the external view of the ttft /
/// inter-token percentiles the server exports on `/metrics`
/// (docs/METRICS.md). PJRT-free, always runs.
fn bench_http_stream_latency(json: &mut JsonRows) -> anyhow::Result<()> {
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::sync::Arc;

    const REQUESTS: usize = 16;
    const MAX_NEW: usize = 64;

    fn count_frames(hay: &[u8], needle: &[u8]) -> usize {
        hay.windows(needle.len()).filter(|w| *w == needle).count()
    }

    let cfg = RouterConfig {
        batcher: BatcherConfig {
            buckets: vec![1, 4],
            max_wait: std::time::Duration::ZERO,
            queue_cap: 256,
        },
        idle_poll: std::time::Duration::from_micros(200),
        ..Default::default()
    };
    let router = Router::spawn(cfg, || Ok(SimCore::new(4, 0x477F, vec![1, 4])))
        .map_err(|e| anyhow::anyhow!("http bench router: {e}"))?;
    let opts = HttpOpts {
        // Small coalescing window: more token frames per stream, so the
        // inter-token sample pool is dense enough for a p50.
        stream_buffer: 4,
        ..Default::default()
    };
    let server = HttpServer::spawn("127.0.0.1:0", Arc::new(router), opts)
        .map_err(|e| anyhow::anyhow!("http bench spawn: {e}"))?;

    let mut ttft_ms: Vec<f64> = Vec::new();
    let mut inter_ms: Vec<f64> = Vec::new();
    let mut frames = 0usize;
    for i in 0..REQUESTS {
        let body = format!("{{\"prompt\": [{}, 2, 3], \"max_new\": {MAX_NEW}}}", i + 1);
        let mut s = TcpStream::connect(server.addr())?;
        s.set_nodelay(true)?;
        s.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
        s.write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nHost: b\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )?;
        let t0 = Instant::now();
        let mut raw = Vec::new();
        let mut stamps: Vec<Instant> = Vec::new(); // one per token frame, arrival order
        let mut buf = [0u8; 4096];
        loop {
            let n = s.read(&mut buf)?;
            if n == 0 {
                break;
            }
            raw.extend_from_slice(&buf[..n]);
            let now = Instant::now();
            // Frames that land in one read genuinely arrived together:
            // they share a stamp (inter-token gap 0 for that pair).
            while stamps.len() < count_frames(&raw, b"event: token\r\n") {
                stamps.push(now);
            }
        }
        anyhow::ensure!(!stamps.is_empty(), "stream {i}: no token frames");
        anyhow::ensure!(
            count_frames(&raw, b"event: done\r\n") == 1,
            "stream {i}: missing done frame"
        );
        frames += stamps.len();
        ttft_ms.push(stamps[0].duration_since(t0).as_secs_f64() * 1e3);
        for w in stamps.windows(2) {
            inter_ms.push(w[1].duration_since(w[0]).as_secs_f64() * 1e3);
        }
    }
    server.shutdown();

    let p50 = |xs: &mut [f64]| {
        xs.sort_by(|a, b| a.total_cmp(b));
        if xs.is_empty() {
            0.0
        } else {
            xs[xs.len() / 2]
        }
    };
    let (ttft_p50, inter_p50) = (p50(&mut ttft_ms), p50(&mut inter_ms));
    let mut table = Table::new(
        "HTTP SSE streaming latency (loopback TCP, SimCore, client-side stamps)",
        &["requests", "tokens", "token frames", "ttft p50 ms", "inter-token p50 ms"],
    );
    table.row(vec![
        REQUESTS.to_string(),
        (REQUESTS * MAX_NEW).to_string(),
        frames.to_string(),
        format!("{ttft_p50:.3}"),
        format!("{inter_p50:.3}"),
    ]);
    table.emit("http_stream_latency")?;
    json.push(vec![
        ("bench", Json::Str("http_stream_latency".into())),
        ("config", Json::Str(format!("simcore stream_buffer=4 n={REQUESTS}x{MAX_NEW}"))),
        ("requests", Json::Num(REQUESTS as f64)),
        ("tokens", Json::Num((REQUESTS * MAX_NEW) as f64)),
        ("events", Json::Num(frames as f64)),
        ("ttft_ms_p50", Json::Num(ttft_p50)),
        ("inter_token_ms_p50", Json::Num(inter_p50)),
    ]);
    Ok(())
}

/// Steady-state device→host transfer per decode round, host vs device
/// verify path, from the closed forms in `server::metrics` at the
/// manifest's own dims (512 vocab, Vt=8, 3d=288 features). Always runs —
/// this is the analytic side of the ISSUE-2 acceptance criterion; the
/// live counter below cross-checks it when artifacts exist.
fn bench_verify_transfer(json: &mut JsonRows) -> anyhow::Result<()> {
    let (vt, vocab, vd, d, f3) = (8usize, 512usize, 320usize, 96usize, 288usize);
    let mut table = Table::new(
        "Verify-path d2h transfer per round (analytic, manifest dims)",
        &["arch", "B", "K", "host B/round", "device B/round", "reduction"],
    );
    for (arch, k) in [("eagle3", 7usize), ("medusa", 6), ("mlp", 6)] {
        for b in [1usize, 4] {
            let host = host_verify_bytes_per_round(b, vt, vocab, f3)
                + host_draft_bytes_per_round(arch, b, k, vocab, vd, d, vt);
            let dev = device_bytes_per_round(b, k, vt);
            table.row(vec![
                arch.to_string(),
                b.to_string(),
                k.to_string(),
                host.to_string(),
                dev.to_string(),
                format!("{:.0}x", host as f64 / dev as f64),
            ]);
            for (path, bytes) in [("host", host), ("device", dev)] {
                json.push(vec![
                    ("bench", Json::Str("verify_transfer_analytic".into())),
                    ("config", Json::Str(format!("{arch} b={b} k={k} {path}"))),
                    ("bytes_to_host", Json::Num(bytes as f64)),
                ]);
            }
        }
    }
    // Multi-candidate rounds (the default 2x2 tree, N = 6 nodes): host
    // traffic still scales with the vocabulary, the fused tree paths
    // stay O(B·N) ints — for the parallel-head AND recurrent backends
    // (the latter pays one [B, Kq, Vd] q pull per expansion level on
    // the host path).
    for b in [1usize, 4] {
        let n = 6;
        for (name, host, dev) in [
            (
                "medusa-tree(2x2)",
                tree_host_bytes_per_round(b, vt, vocab, f3, 6),
                tree_device_bytes_per_round(b, n, vt),
            ),
            (
                "recurrent-tree(2x2)",
                recurrent_tree_host_bytes_per_round(b, vt, vocab, f3, 2, vd, d),
                recurrent_tree_device_bytes_per_round(b, n, vt),
            ),
        ] {
            table.row(vec![
                name.to_string(),
                b.to_string(),
                n.to_string(),
                host.to_string(),
                dev.to_string(),
                format!("{:.0}x", host as f64 / dev as f64),
            ]);
            for (path, bytes) in [("host", host), ("device", dev)] {
                json.push(vec![
                    ("bench", Json::Str("verify_transfer_analytic".into())),
                    ("config", Json::Str(format!("{name} b={b} n={n} {path}"))),
                    ("bytes_to_host", Json::Num(bytes as f64)),
                ]);
            }
        }
    }
    table.emit("verify_transfer")?;
    Ok(())
}

/// Live `bytes_to_host_per_round` on the real engine, forced host vs
/// forced device, proving the analytic table against the runtime's
/// `output_host` accounting. Needs artifacts + the dense-s/eagle3
/// checkpoints (skips quietly otherwise, like the end-to-end section).
fn bench_live_transfer(rt: &Runtime, dirs: &RunDirs, json: &mut JsonRows) -> anyhow::Result<()> {
    use lk_spec::server::engine::{AdaptiveOpts, EngineOpts, SpecEngine, VerifyPath};
    use lk_spec::tensor::read_checkpoint;
    use lk_spec::util::Json;
    if !rt.has_target_entry("dense-s", "verify_fused_b1") {
        println!("live transfer: artifacts predate device verify — host path only");
        return Ok(());
    }
    let tckpt = read_checkpoint(&dirs.target_ckpt("dense-s"))?;
    let dckpt = read_checkpoint(&dirs.draft_ckpt("eagle3_dense-s__kl"))?;
    let vm: Vec<i32> = Json::parse_file(&dirs.vocab_map())?
        .get("map")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|x| x.as_i64().unwrap_or(0) as i32)
        .collect();
    let mut table = Table::new(
        "Verify-path d2h transfer per round (measured, eagle3@dense-s b=1)",
        &["path", "bytes/round"],
    );
    for path in [VerifyPath::Host, VerifyPath::Device] {
        let mut engine = SpecEngine::new(
            rt,
            "eagle3@dense-s",
            &tckpt,
            &dckpt,
            Some(vm.clone()),
            EngineOpts {
                verify_path: path,
                // Fixed k: the analytic closed forms beside this table
                // assume the full chain every round.
                adaptive: AdaptiveOpts::fixed(),
                ..Default::default()
            },
        )?;
        let prompt: Vec<i32> = vec![5, 6, 7, 8];
        let _ = engine.generate_batch(std::slice::from_ref(&prompt), 24)?;
        table.row(vec![
            engine.verify_path().to_string(),
            format!("{:.0}", engine.metrics.bytes_to_host_per_round()),
        ]);
        json.push(vec![
            ("bench", Json::Str("verify_transfer_live".into())),
            ("config", Json::Str(format!("eagle3@dense-s b=1 {}", engine.verify_path()))),
            ("rounds", Json::Num(engine.metrics.decode_rounds as f64)),
            ("accepted_len_mean", Json::Num(engine.metrics.mean_accepted_len())),
            ("bytes_to_host", Json::Num(engine.metrics.bytes_to_host_per_round())),
        ]);
    }
    table.emit("verify_transfer_live")?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // Every section appends machine-readable rows; the file is written
    // on every exit path so the perf trajectory accumulates even on
    // artifact-less runs (CI uploads it).
    let mut json = JsonRows::new();
    let result = run_sections(&mut json);
    json.write("BENCH_engine.json")?;
    println!("wrote results/BENCH_engine.json ({} rows)", json.len());
    result
}

fn run_sections(json: &mut JsonRows) -> anyhow::Result<()> {
    bench_scheduler_overhead()?;
    bench_paged_kv_capacity(json)?;
    bench_kv_migration_analytic(json)?;
    bench_speculation_controller(json)?;
    bench_chaos_smoke(json)?;
    bench_prefill_interference(json)?;
    bench_adaptation_drift(json)?;
    bench_http_stream_latency(json)?;
    bench_verify_transfer(json)?;
    if !Path::new("artifacts/manifest.json").exists() {
        skip("artifacts missing");
        return Ok(());
    }
    let rt = Runtime::new(Path::new("artifacts"))?;

    // --- per-executable dispatch costs -----------------------------------
    let mut table = Table::new(
        "Engine hot path — per-executable dispatch cost (dense-s)",
        &["executable", "mean ms", "p95 ms"],
    );
    for (kind, name, entry) in [
        ("tgt", "dense-s", "decode_b1"),
        ("tgt", "dense-s", "verify_b1"),
        ("tgt", "dense-s", "verify_b4"),
        ("tgt", "dense-s", "prefill_b4"),
        ("dr", "eagle3@dense-s", "step_b1"),
        ("dr", "eagle3@dense-s", "step_b4"),
        ("dr", "eagle3@dense-s", "extend_k_b4"),
    ] {
        let exe = if kind == "tgt" {
            rt.target_entry(name, entry)?
        } else {
            rt.draft_entry(name, entry)?
        };
        let args: Vec<HostTensor> = exe
            .spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(s.dtype, &s.shape))
            .collect();
        let r = bench(entry, 3, 20, || {
            let _ = exe.run(&args).unwrap();
        });
        table.row(vec![
            format!("{name}:{entry}"),
            format!("{:.2}", r.mean_ms),
            format!("{:.2}", r.p95_ms),
        ]);
    }
    table.emit("engine_hotpath")?;

    // --- end-to-end round decomposition ----------------------------------
    let dirs = RunDirs::new(Path::new("runs"));
    if !dirs.target_ckpt("dense-s").exists()
        || !dirs.draft_ckpt("eagle3_dense-s__kl").exists()
    {
        skip("checkpoints missing — per-executable numbers above still valid");
        return Ok(());
    }
    bench_live_transfer(&rt, &dirs, json)?;
    let corpus = Corpus::open(Path::new("data"))?;
    // Standard settings so this re-evaluation is interchangeable with the
    // cached cell it refreshes (same cell name => must be same protocol).
    let settings = EvalSettings::default();
    let t0 = std::time::Instant::now();
    let cell = lk_spec::eval::eval_cell(
        &rt, &dirs, &corpus, "eagle3@dense-s", "kl", Domain::Chat, EvalMode::T1,
        7, &settings, true,
    )?;
    let wall = t0.elapsed().as_secs_f64();
    let exec: f64 = rt.exec_report().iter().map(|(_, _, ms)| ms / 1e3).sum();
    println!(
        "end-to-end: wall {wall:.2}s, XLA-exec {exec:.2}s, engine overhead {:.1}% \
         (dense-s is the host-bound worst case: sub-ms executables — see \
         EXPERIMENTS.md §Perf; deeper targets are XLA-bound), tau {:.2}, \
         spec {:.1} tok/s vs vanilla {:.1} tok/s",
        (1.0 - exec / wall).max(0.0) * 100.0,
        cell.tau,
        cell.spec_tps,
        cell.vanilla_tps,
    );
    for (name, calls, ms) in rt.exec_report().iter().take(8) {
        println!("  {name}: {calls} calls, {ms:.0} ms");
    }
    json.push(vec![
        ("bench", Json::Str("end_to_end".into())),
        ("config", Json::Str("eagle3@dense-s kl chat t1 k=7".into())),
        ("tok_s", Json::Num(cell.spec_tps)),
        ("vanilla_tok_s", Json::Num(cell.vanilla_tps)),
        ("tau", Json::Num(cell.tau)),
    ]);
    Ok(())
}
