//! Table 1: average acceptance length τ for the Llama-3.1-8B analog
//! (`dense-s`) — EAGLE-3 / MEDUSA / MLP speculators × the full objective
//! sweep × {MT-Bench, HumanEval, GSM8K} analogs × T∈{0,1}.
//!
//! Reads the cached evaluation cells produced by `lk-spec eval-all`;
//! writes results/table1_llama8b.md and checks the paper's shape claims
//! (§6.1): LK^λ/LK^α ≥ KL, TV ≪ KL, fixed-λ ≈ KL, adaptive λ best.

use lk_spec::bench::{fmt, skip, Table};
use lk_spec::config::plan;
use lk_spec::data::grammar::DOMAINS;
use lk_spec::eval::{cached_cell, EvalMode};
use lk_spec::train::RunDirs;

fn main() -> anyhow::Result<()> {
    let dirs = RunDirs::new(std::path::Path::new("runs"));
    let runs = plan::table1();
    let mut missing = 0usize;

    let mut table = Table::new(
        "Table 1 — τ for LLaMA-3.1-8B analog (dense-s): EAGLE-3 / MEDUSA / MLP × objectives",
        &["arch", "loss", "T", "chat (MT)", "code (HE)", "math (GSM)", "mean"],
    );
    // (arch, loss, mode) -> mean tau, for shape checks
    let mut means = std::collections::BTreeMap::new();
    for mode in [EvalMode::T0, EvalMode::T1] {
        for r in &runs {
            let arch = r.draft.split('@').next().unwrap().to_string();
            let k = if arch == "eagle3" { 7 } else { 6 };
            let mut taus = Vec::new();
            for domain in DOMAINS {
                match cached_cell(&dirs, &r.draft, &r.loss.tag, domain, mode, k) {
                    Some(c) => taus.push(c.tau),
                    None => {
                        missing += 1;
                        taus.push(f64::NAN);
                    }
                }
            }
            let mean = taus.iter().sum::<f64>() / taus.len() as f64;
            means.insert((arch.clone(), r.loss.tag.clone(), mode.tag()), mean);
            table.row(vec![
                arch,
                r.loss.label.clone(),
                if mode == EvalMode::T0 { "0" } else { "1" }.into(),
                fmt(taus[0], 3),
                fmt(taus[1], 3),
                fmt(taus[2], 3),
                fmt(mean, 3),
            ]);
        }
    }
    if missing > 0 {
        skip(&format!("{missing} cells missing"));
        return Ok(());
    }
    table.emit("table1_llama8b")?;

    // ---- paper shape checks (§6.1) --------------------------------------
    let get = |arch: &str, tag: &str, mode: &str| means[&(arch.into(), tag.into(), mode.into())];
    let mut ok = true;
    let mut check = |name: &str, cond: bool| {
        println!("  {} {name}", if cond { "PASS" } else { "MISS" });
        ok &= cond;
    };
    check(
        "TV far below KL (gradient pathology, §4.1)",
        get("eagle3", "tv", "t1") < get("eagle3", "kl", "t1") - 0.1,
    );
    check(
        "LK^λ(η=3) beats KL at T=1 (EAGLE-3)",
        get("eagle3", "lkl-eta3", "t1") > get("eagle3", "kl", "t1"),
    );
    check(
        "LK^α beats KL at T=1 (EAGLE-3)",
        get("eagle3", "lka", "t1") > get("eagle3", "kl", "t1"),
    );
    check(
        "best adaptive η beats fixed λ=0.5",
        [0.7, 1.0, 3.0, 10.0]
            .iter()
            .map(|eta| get("eagle3", &format!("lkl-eta{eta}"), "t1"))
            .fold(f64::MIN, f64::max)
            > get("eagle3", "lkl-fixed0.5", "t1"),
    );
    check(
        "MEDUSA: LK^λ(η=10) ≥ KL at T=1",
        get("medusa", "lkl-eta10", "t1") >= get("medusa", "kl", "t1") - 1e-9,
    );
    check(
        "MLP: LK^λ(η=3) ≥ KL at T=1",
        get("mlp", "lkl-eta3", "t1") >= get("mlp", "kl", "t1") - 1e-9,
    );
    println!(
        "shape checks {}",
        if ok { "ALL PASS" } else { "— some missed (see EXPERIMENTS.md discussion)" }
    );
    Ok(())
}
