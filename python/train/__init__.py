"""Background fine-tune entry points for the online adaptation loop
(DESIGN.md §12). Stdlib-only: the trainer must run on minimal CI images
with no jax installed."""
