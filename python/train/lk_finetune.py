#!/usr/bin/env python3
"""Background LK fine-tune over harvested acceptance transcripts
(DESIGN.md §12).

The serving engine's `AdaptDriver` launches this script as

    lk_finetune.py --config <epoch_dir>/config.json

with config keys `{"transcript", "out_dir", "epoch", "gain"}`, and reads
JSONL protocol events from stdout (`{"kind": .., "payload": ..}` lines,
flushed per event). The final event must be
`{"kind": "done", "payload": {"checkpoint", "epoch", "alpha_before",
"alpha_after"}}`; an `{"kind": "error"}` event or a non-zero exit maps to
a typed, transient trainer fault on the serving side — stale draft
weights keep serving.

Two modes:

* ``sim`` (default): the deterministic acceptance-profile fit mirrored
  in-process by the Rust ``sim_finetune`` — per-slot empirical
  acceptance over the transcript, then a fitted profile closing
  fraction ``gain`` of each slot's acceptance gap.
* ``lk``: the LK objectives from the paper on the harvested support.
  Each record collapses target/draft to a two-atom Bernoulli pair
  ``P=(p, 1-p)``, ``Q=(q, 1-q)`` over {drafted token, rest}; a per-slot
  interpolation knob ``theta_n`` moves the draft toward the target
  (``q' = (1-theta)·q + theta·p`` — the stylized effect of distilling on
  one's own rejections), trained by finite-difference descent on
  ``sum_n gamma^n · lambda_n · L_n(theta_n)`` with the adaptive
  ``lambda_n = exp(-eta · alpha_hat_n)`` schedule and
  ``L = w_kl·KL + w_tv·TV + w_nll·(-log alpha)``.

Both modes emit, atomically (tmp + ``os.replace``, matching every
checkpoint writer in the repo):

* ``draft_sim.json`` — the ``lkspec-sim-draft`` profile checkpoint the
  serving side validates-then-commits at a round boundary;
* ``draft_lk.lkt`` — an LKT1 tensor checkpoint (theta + fitted profile)
  byte-compatible with ``rust/src/tensor/checkpoint.rs``;
* ``manifest.json`` — the re-emitted adaptation manifest pointing at
  both, so a restarted server can find the newest epoch.

Everything here is importable (``from train import lk_finetune``) and
covered by ``python/tests/test_lk_finetune.py``.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import struct
import sys
from typing import Any

# ---------------------------------------------------------------------------
# transcript
# ---------------------------------------------------------------------------


def load_transcript(path: str) -> list[dict[str, Any]]:
    """Parse the harvested replay transcript (one JSON record per line:
    session/round/pos/slot/ctx/draft/accept, optional q/p)."""
    records = []
    with open(path, "r", encoding="utf-8") as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: bad transcript line: {e}") from e
            for key in ("slot", "accept"):
                if key not in rec:
                    raise ValueError(f"{path}:{i + 1}: record missing '{key}'")
            records.append(rec)
    return records


# ---------------------------------------------------------------------------
# sim fit (must mirror rust sim_finetune bit-for-bit in float64)
# ---------------------------------------------------------------------------


def sim_fit(records: list[dict[str, Any]], k: int, gain: float):
    """Per-slot empirical acceptance, then a fitted profile closing
    fraction `gain` of each slot's acceptance gap. Slots never exercised
    inherit the previous slot's fitted estimate (deep slots only run
    after shallow accepts). Returns (profile, alpha_before, alpha_after).
    """
    k = max(k, 1)
    acc = [0] * k
    tot = [0] * k
    for r in records:
        s = min(int(r["slot"]), k - 1)
        tot[s] += 1
        acc[s] += 1 if r["accept"] else 0
    gain = min(max(gain, 0.0), 1.0)
    profile: list[float] = []
    a_n = a_d = 0.0
    for i in range(k):
        if tot[i] > 0:
            a_n += acc[i]
            a_d += tot[i]
            alpha = acc[i] / tot[i]
        else:
            alpha = profile[-1] if profile else 0.5
        profile.append(min(max(alpha + gain * (1.0 - alpha), 0.0), 1.0))
    alpha_before = a_n / a_d if a_d > 0 else 0.0
    alpha_after = alpha_before + gain * (1.0 - alpha_before)
    return profile, alpha_before, alpha_after


# ---------------------------------------------------------------------------
# LK objectives on the two-atom collapse
# ---------------------------------------------------------------------------

_EPS = 1e-12


def lk_terms_2atom(p: float, q: float) -> dict[str, float]:
    """LK loss terms for the Bernoulli pair P=(p, 1-p), Q=(q, 1-q) over
    {drafted token, everything else}: acceptance alpha = sum min(P, Q) =
    1 - |p - q|, total variation, KL(P || Q), and -log alpha."""
    p = min(max(p, 0.0), 1.0)
    q = min(max(q, 0.0), 1.0)
    tv = abs(p - q)
    alpha = 1.0 - tv
    qc, pc = max(q, _EPS), max(1.0 - q, _EPS)
    kl = 0.0
    if p > 0.0:
        kl += p * math.log(p / qc)
    if p < 1.0:
        kl += (1.0 - p) * math.log((1.0 - p) / pc)
    return {
        "alpha": alpha,
        "tv": tv,
        "kl": kl,
        "nll": -math.log(max(alpha, _EPS)),
    }


def _slot_loss(pairs, theta, weights):
    """Mean LK loss over one slot's (p, q) pairs with the draft moved
    toward the target by theta: q' = (1-theta)·q + theta·p."""
    w_kl, w_tv, w_nll = weights
    total = 0.0
    for p, q in pairs:
        t = lk_terms_2atom(p, (1.0 - theta) * q + theta * p)
        total += w_kl * t["kl"] + w_tv * t["tv"] + w_nll * t["nll"]
    return total / len(pairs)


def lk_fit(
    records,
    k,
    gain,
    steps=60,
    lr=0.5,
    eta=1.0,
    gamma=0.9,
    weights=(1.0, 1.0, 1.0),
    on_step=None,
):
    """Fit per-slot theta by finite-difference descent on the weighted
    LK objective; slots without (p, q) evidence fall back to the sim fit.
    Returns (profile, alpha_before, alpha_after, theta)."""
    k = max(k, 1)
    by_slot: list[list[tuple[float, float]]] = [[] for _ in range(k)]
    for r in records:
        if "p" in r and "q" in r:
            by_slot[min(int(r["slot"]), k - 1)].append((float(r["p"]), float(r["q"])))
    sim_profile, alpha_before, _ = sim_fit(records, k, gain)
    # Adaptive lambda is frozen at the pre-fit acceptance (sg[alpha]).
    alpha_hat = [
        (sum(1.0 - abs(p - q) for p, q in pairs) / len(pairs)) if pairs else 0.0
        for pairs in by_slot
    ]
    theta = [0.0] * k
    eps = 1e-3
    for step in range(steps):
        loss = 0.0
        for n, pairs in enumerate(by_slot):
            if not pairs:
                continue
            scale = (gamma**n) * math.exp(-eta * alpha_hat[n])
            grad = (
                _slot_loss(pairs, min(theta[n] + eps, 1.0), weights)
                - _slot_loss(pairs, max(theta[n] - eps, 0.0), weights)
            ) / (2.0 * eps)
            theta[n] = min(max(theta[n] - lr * scale * grad, 0.0), 1.0)
            loss += scale * _slot_loss(pairs, theta[n], weights)
        if on_step is not None:
            on_step(step, loss)
    profile = []
    for n, pairs in enumerate(by_slot):
        if pairs:
            a = sum(1.0 - (1.0 - theta[n]) * abs(p - q) for p, q in pairs) / len(pairs)
            profile.append(min(max(a, 0.0), 1.0))
        else:
            profile.append(sim_profile[n])
    tot = [len(pairs) for pairs in by_slot]
    n_rec = sum(tot)
    alpha_after = (
        sum(t * a for t, a in zip(tot, profile)) / n_rec if n_rec else profile[0]
    )
    return profile, alpha_before, alpha_after, theta


# ---------------------------------------------------------------------------
# checkpoint writers (atomic; LKT1 byte-compatible with the Rust reader)
# ---------------------------------------------------------------------------

_LKT_MAGIC = b"LKT1"
_DTYPE_CODE = {"f32": 0, "i32": 1, "u32": 2}
_DTYPE_PACK = {"f32": "f", "i32": "i", "u32": "I"}
_CODE_DTYPE = {v: k for k, v in _DTYPE_CODE.items()}


def _atomic_write(path: str, data: bytes) -> None:
    """tmp + os.replace so a killed writer never commits a torn file."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def write_lkt(path: str, meta: dict[str, Any], tensors: dict[str, tuple]) -> None:
    """Write an LKT1 checkpoint: `tensors` maps name -> (dtype, shape,
    flat values) with dtype in {f32, i32, u32}. Matches the layout in
    rust/src/tensor/checkpoint.rs (all integers little-endian)."""
    out = bytearray(_LKT_MAGIC)
    meta_bytes = json.dumps(meta).encode("utf-8")
    out += struct.pack("<I", len(meta_bytes)) + meta_bytes
    out += struct.pack("<I", len(tensors))
    for name in sorted(tensors):
        dtype, shape, values = tensors[name]
        n = 1
        for d in shape:
            n *= d
        if n != len(values):
            raise ValueError(f"tensor '{name}': shape {shape} != {len(values)} values")
        name_bytes = name.encode("utf-8")
        out += struct.pack("<I", len(name_bytes)) + name_bytes
        out += struct.pack("<BB", _DTYPE_CODE[dtype], len(shape))
        for d in shape:
            out += struct.pack("<I", d)
        out += struct.pack(f"<{n}{_DTYPE_PACK[dtype]}", *values)
    _atomic_write(path, bytes(out))


def read_lkt(path: str):
    """Read + fully validate an LKT1 checkpoint; returns (meta, tensors)
    with tensors mapping name -> (dtype, shape, flat values)."""
    with open(path, "rb") as f:
        data = f.read()

    off = 0

    def take(n: int) -> bytes:
        nonlocal off
        if off + n > len(data):
            raise ValueError(f"{path}: truncated at byte {off} (+{n})")
        chunk = data[off : off + n]
        off += n
        return chunk

    if take(4) != _LKT_MAGIC:
        raise ValueError(f"{path}: not an LKT1 checkpoint")
    meta_len = struct.unpack("<I", take(4))[0]
    meta = json.loads(take(meta_len).decode("utf-8"))
    count = struct.unpack("<I", take(4))[0]
    tensors = {}
    for _ in range(count):
        name_len = struct.unpack("<I", take(4))[0]
        name = take(name_len).decode("utf-8")
        code, rank = struct.unpack("<BB", take(2))
        if code not in _CODE_DTYPE:
            raise ValueError(f"{path}: bad dtype code {code} for '{name}'")
        dtype = _CODE_DTYPE[code]
        shape = [struct.unpack("<I", take(4))[0] for _ in range(rank)]
        n = 1
        for d in shape:
            n *= d
        values = list(struct.unpack(f"<{n}{_DTYPE_PACK[dtype]}", take(4 * n)))
        tensors[name] = (dtype, shape, values)
    if off != len(data):
        raise ValueError(f"{path}: {len(data) - off} trailing bytes")
    return meta, tensors


def write_sim_checkpoint(path, epoch, profile, alpha_before, alpha_after):
    """The `lkspec-sim-draft` profile checkpoint SimCore's
    validate-then-commit hot-swap consumes."""
    doc = {
        "format": "lkspec-sim-draft",
        "epoch": epoch,
        "profile": profile,
        "alpha_before": alpha_before,
        "alpha_after": alpha_after,
    }
    _atomic_write(path, (json.dumps(doc, indent=2) + "\n").encode("utf-8"))


def write_manifest(out_dir, epoch, mode, checkpoint, lkt, alpha_before, alpha_after, n):
    """Re-emit the adaptation manifest so a restarted server (or the
    next fine-tune) can locate the newest epoch's artifacts."""
    doc = {
        "format": "lkspec-adapt-manifest",
        "epoch": epoch,
        "mode": mode,
        "checkpoint": checkpoint,
        "lkt": lkt,
        "alpha_before": alpha_before,
        "alpha_after": alpha_after,
        "records": n,
    }
    _atomic_write(
        os.path.join(out_dir, "manifest.json"),
        (json.dumps(doc, indent=2) + "\n").encode("utf-8"),
    )


# ---------------------------------------------------------------------------
# protocol + entry point
# ---------------------------------------------------------------------------


def emit(kind: str, payload: dict[str, Any], out=None) -> None:
    """One protocol event, flushed: the serving side treats any
    non-`{"kind", "payload"}` stdout line as a malformed-protocol fault
    and an event gap past the deadline as a hang."""
    out = out or sys.stdout
    out.write(json.dumps({"kind": kind, "payload": payload}) + "\n")
    out.flush()


def run(config_path: str, mode_override: str | None = None) -> int:
    with open(config_path, "r", encoding="utf-8") as f:
        cfg = json.load(f)
    transcript = cfg["transcript"]
    out_dir = cfg["out_dir"]
    epoch = int(cfg.get("epoch", 0))
    gain = float(cfg.get("gain", 0.5))
    mode = mode_override or cfg.get("mode", "sim")
    if mode not in ("sim", "lk"):
        raise ValueError(f"unknown mode '{mode}' (expected sim or lk)")

    records = load_transcript(transcript)
    if not records:
        raise ValueError(f"{transcript}: empty transcript")
    k = 1 + max(int(r["slot"]) for r in records)
    emit("start", {"epoch": epoch, "mode": mode, "records": len(records), "k": k})

    if mode == "sim":
        profile, a0, a1 = sim_fit(records, k, gain)
        theta = [0.0] * k
        emit("progress", {"step": 0, "loss": 1.0 - a0})
    else:
        steps = int(cfg.get("steps", 60))
        profile, a0, a1, theta = lk_fit(
            records,
            k,
            gain,
            steps=steps,
            lr=float(cfg.get("lr", 0.5)),
            eta=float(cfg.get("eta", 1.0)),
            gamma=float(cfg.get("gamma", 0.9)),
            on_step=lambda step, loss: (
                emit("progress", {"step": step, "loss": loss})
                if step % 10 == 0 or step == steps - 1
                else None
            ),
        )

    ckpt = os.path.join(out_dir, "draft_sim.json")
    lkt = os.path.join(out_dir, "draft_lk.lkt")
    write_sim_checkpoint(ckpt, epoch, profile, a0, a1)
    write_lkt(
        lkt,
        {
            "epoch": epoch,
            "mode": mode,
            "alpha_before": a0,
            "alpha_after": a1,
            "records": len(records),
        },
        {
            "adapt/theta": ("f32", [k], theta),
            "adapt/profile": ("f32", [k], profile),
        },
    )
    write_manifest(out_dir, epoch, mode, ckpt, lkt, a0, a1, len(records))
    emit(
        "done",
        {"checkpoint": ckpt, "epoch": epoch, "alpha_before": a0, "alpha_after": a1},
    )
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--config", required=True, help="JSON config from AdaptDriver")
    ap.add_argument("--mode", choices=("sim", "lk"), help="override config mode")
    args = ap.parse_args(argv)
    try:
        return run(args.config, args.mode)
    except Exception as e:  # contained: maps to a typed transient fault
        emit("error", {"message": f"{type(e).__name__}: {e}"})
        return 1


if __name__ == "__main__":
    sys.exit(main())
