"""Device-verify correctness: the blocked Pallas round
(`kernels.fused_verify`) against the jnp serving graph
(`compile.verify_device`) against a literal transcription of the Rust
host path (`spec::sampling::verify_round`) — the three implementations
whose agreement the engine's host/device parity rests on.

Deliberately hypothesis-free so the suite runs on minimal images; the
randomized sweeps are seeded and exhaustive over (mode, block size).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import verify_device as VD
from compile.kernels import fused_verify


def rand(key, shape, scale):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# host-path mirrors (keep in lockstep with rust/src/spec/sampling.rs)
# ---------------------------------------------------------------------------

def _host_categorical(p, u):
    """Mirror of `spec::sampling::categorical_from_uniform`."""
    c = 0.0
    for i, x in enumerate(p):
        c += x
        if c >= u:
            return i
    nz = [i for i, x in enumerate(p) if x > 0]
    return nz[-1] if nz else len(p) - 1


def _host_verify_round(logits, q, drafted, u_acc, u_samp, temp, mode, k_active):
    """Mirror of `spec::sampling::verify_round` (the Rust host path)."""
    k1, _ = logits.shape

    def softmax_t(z, t):
        z = z / max(t, 1e-3)
        e = np.exp(z - z.max())
        return e / e.sum()

    p = np.stack([softmax_t(logits[j], temp) for j in range(k1)])
    j = 0
    while j < k_active:
        x = drafted[j]
        if mode == VD.MODE_GREEDY:
            ok = int(np.argmax(p[j])) == x
        elif mode == VD.MODE_STOCHASTIC:
            beta = min(1.0, p[j][x] / q[j][x]) if q[j][x] > 0 else 0.0
            ok = u_acc[j] < beta
        else:  # greedy-draft (Appendix D): beta = min(1, p(x))
            ok = u_acc[j] < min(1.0, p[j][x])
        if not ok:
            break
        j += 1
    if mode == VD.MODE_GREEDY:
        tok = int(np.argmax(p[j]))
    elif j >= k_active:
        tok = _host_categorical(p[j], u_samp)  # bonus
    else:
        res = np.maximum(p[j] - q[j], 0.0)
        z = res.sum()
        if z > 0:
            tok = _host_categorical(res / max(z, 1e-30), u_samp)
        else:
            tok = _host_categorical(p[j], u_samp)  # p == q fallback
    return j, tok


# ---------------------------------------------------------------------------
# three-way agreement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [0, 1, 2])
@pytest.mark.parametrize("vb", [16, 64])
def test_kernel_matches_device_graph_and_host_loop(mode, vb):
    rng = np.random.default_rng(100 + mode)
    for trial in range(40):
        k1, v = 8, 64
        k = k1 - 1
        temp = float(rng.choice([0.7, 1.0, 1.5]))
        k_active = int(rng.integers(1, k + 1))
        logits = rng.normal(0, 2, (k1, v)).astype(np.float32)
        q = np.asarray(
            jax.nn.softmax(jnp.asarray(rng.normal(0, 2, (k, v)), jnp.float32))
        )
        drafted = rng.integers(0, v, k).astype(np.int32)
        u_acc = rng.random(k).astype(np.float32)
        u_samp = np.float32(rng.random())
        args = (
            jnp.asarray(logits), jnp.asarray(q), jnp.asarray(drafted),
            jnp.asarray(u_acc), jnp.asarray(u_samp), jnp.float32(temp),
            jnp.int32(mode), jnp.int32(k_active),
        )
        na_k, tok_k = fused_verify.fused_verify_row(*args, vocab_block=vb)
        na_g, tok_g = VD._verify_row(*args)
        assert int(na_k) == int(na_g), trial
        np.testing.assert_array_equal(
            np.asarray(tok_k)[: int(na_g) + 1],
            np.asarray(tok_g)[: int(na_g) + 1],
        )
        hj, htok = _host_verify_round(
            logits.astype(np.float64), q.astype(np.float64), drafted,
            u_acc, float(u_samp), temp, mode, k_active,
        )
        assert int(na_g) == hj, trial
        assert int(np.asarray(tok_g)[hj]) == htok, trial


def test_accepts_all_when_q_equals_p():
    p_logits = rand(30, (8, 64), 2.0)
    q = jax.nn.softmax(p_logits)[:7]
    drafted = jnp.arange(7, dtype=jnp.int32)
    n_acc, toks = fused_verify.fused_verify_row(
        p_logits, q, drafted, jnp.full((7,), 0.999, jnp.float32),
        jnp.float32(0.5), jnp.float32(1.0), jnp.int32(1), jnp.int32(7),
        vocab_block=16,
    )
    assert int(n_acc) == 7  # beta == 1 everywhere when q == p
    np.testing.assert_array_equal(np.asarray(toks)[:7], np.arange(7))


def test_k_active_caps_acceptance():
    """Short chains (k < K) must stop at k_active and emit a bonus there —
    the zero-padded q inputs beyond k_active may never be 'accepted'."""
    p_logits = rand(31, (8, 64), 2.0)
    q = jax.nn.softmax(p_logits)[:7]
    drafted = jnp.arange(7, dtype=jnp.int32)
    for ka in (1, 3):
        n_acc, _ = fused_verify.fused_verify_row(
            p_logits, q, drafted, jnp.full((7,), 0.0, jnp.float32),
            jnp.float32(0.5), jnp.float32(1.0), jnp.int32(1), jnp.int32(ka),
            vocab_block=16,
        )
        assert int(n_acc) == ka


def test_preserves_target_distribution():
    """Leviathan Thm. 1 on the fused path: accepted-or-replacement output
    of a k=1 round is distributed exactly as p (the same machinery as
    `spec::sampling::rejection_sampling_preserves_target`)."""
    rng = np.random.default_rng(9)
    v = 16
    logits = rng.normal(0, 2, (1, 2, v)).astype(np.float32)
    q = np.asarray(
        jax.nn.softmax(jnp.asarray(rng.normal(0, 2, (v,)), jnp.float32))
    )

    def p_of(z):
        e = np.exp(z - z.max())
        return e / e.sum()

    p = p_of(logits[0, 0])
    n = 40_000
    drafted = np.array(
        [_host_categorical(q, u) for u in rng.random(n)], np.int32
    )
    n_acc, toks = VD.fused_verify(
        jnp.broadcast_to(jnp.asarray(logits), (n, 2, v)),
        jnp.broadcast_to(jnp.asarray(q, jnp.float32)[None, None], (n, 1, v)),
        jnp.asarray(drafted)[:, None],
        jnp.asarray(rng.random((n, 1)), jnp.float32),
        jnp.asarray(rng.random(n), jnp.float32),
        jnp.float32(1.0), jnp.int32(1), jnp.int32(1),
    )
    emitted = np.asarray(toks)[:, 0]  # accepted draft or its replacement
    counts = np.bincount(emitted, minlength=v) / n
    np.testing.assert_allclose(counts, p, atol=0.012)


def test_categorical_from_uniform_edges():
    # fp slack past the total mass falls back to the last positive index
    p = jnp.array([0.3, 0.0, 0.2, 0.0], jnp.float32)
    assert int(VD.categorical_from_uniform(p, jnp.float32(0.9))) == 2
    assert int(VD.categorical_from_uniform(p, jnp.float32(0.1))) == 0
    assert int(VD.categorical_from_uniform(p, jnp.float32(0.35))) == 2


def test_draft_sample_scatters_truncated_vocab():
    rng = np.random.default_rng(7)
    vm = jnp.asarray(np.sort(rng.choice(64, 16, replace=False)).astype(np.int32))
    logits = jnp.asarray(rng.normal(0, 1, (4, 16)).astype(np.float32))
    tok, qf = VD.draft_q_and_sample(
        logits, jnp.asarray(rng.random(4).astype(np.float32)),
        jnp.float32(1.0), jnp.int32(1), vm, 64,
    )
    assert tok.shape == (4,) and qf.shape == (4, 64)
    np.testing.assert_allclose(np.asarray(qf).sum(-1), 1.0, atol=1e-5)
    allowed = set(np.asarray(vm).tolist())
    assert all(int(t) in allowed for t in tok)
    off = np.setdiff1d(np.arange(64), np.asarray(vm))
    assert np.all(np.asarray(qf)[:, off] == 0.0)


def test_pick_hidden_gathers_last_slice():
    rng = np.random.default_rng(3)
    f = jnp.asarray(rng.normal(0, 1, (2, 5, 12)), jnp.float32)
    sel = jnp.array([3, 0], jnp.int32)
    h = VD.pick_hidden(f, sel, 4)
    assert h.shape == (2, 4)
    np.testing.assert_allclose(np.asarray(h)[0], np.asarray(f)[0, 3, 8:])
    np.testing.assert_allclose(np.asarray(h)[1], np.asarray(f)[1, 0, 8:])
