"""L2 loss tests: closed-form custom-VJP gradients (paper Appendix A) vs
autodiff of the reference implementation; the adaptive λ schedule; head
aggregation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, strategies as st

from compile import losses
from compile.kernels import ref


def rand(key, shape, scale=2.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# gradient identities (Appendix A)
# ---------------------------------------------------------------------------

@given(scale=st.sampled_from([0.3, 2.0, 6.0]), seed=st.integers(0, 5))
def test_full_vocab_grads_match_autodiff(scale, seed):
    zp = rand(seed, (32, 256), scale)
    zq = rand(seed + 100, (32, 256), scale)
    for sel in (
        lambda t: t["kl"],
        lambda t: t["tv"],
        lambda t: -jnp.log(jnp.maximum(t["alpha"], 1e-12)),
    ):
        g_fused = jax.grad(lambda z: jnp.mean(sel(losses.lk_terms(zp, z))))(zq)
        g_ref = jax.grad(lambda z: jnp.mean(sel(ref.lk_terms(zp, z))))(zq)
        np.testing.assert_allclose(g_fused, g_ref, rtol=5e-4, atol=1e-7)


def test_truncated_grads_match_autodiff():
    zp = rand(1, (16, 512), 3.0)
    zq = rand(2, (16, 320), 2.0)
    vm = jnp.sort(
        jax.random.permutation(jax.random.PRNGKey(3), 512)[:320].astype(jnp.int32)
    )
    for sel in (
        lambda t: t["kl"],
        lambda t: t["tv"],
        lambda t: -jnp.log(jnp.maximum(t["alpha"], 1e-12)),
    ):
        g_fused = jax.grad(
            lambda z: jnp.mean(sel(losses.lk_terms(zp, z, vocab_map=vm)))
        )(zq)
        g_ref = jax.grad(
            lambda z: jnp.mean(sel(ref.lk_terms_truncated(zp, z, vm)))
        )(zq)
        np.testing.assert_allclose(g_fused, g_ref, rtol=5e-4, atol=1e-7)


def test_grad_identity_a4():
    """∇(−log α) == (1/α) ∇TV — with ∇TV = −½ ∇α this is Appendix A.4."""
    zp = rand(4, (8, 128), 2.0)
    zq = rand(5, (8, 128), 2.0)
    g_nla = jax.grad(
        lambda z: jnp.sum(-jnp.log(losses.lk_terms(zp, z)["alpha"]))
    )(zq)
    t = losses.lk_terms(zp, zq)
    # rowwise: g_tv / alpha ... compare via ref formulas
    p = jax.nn.softmax(zp)
    q = jax.nn.softmax(zq)
    g_expected = ref.grad_log_alpha_loss(p, q, t["alpha"])
    np.testing.assert_allclose(g_nla, g_expected, rtol=1e-4, atol=1e-7)


def test_target_side_frozen():
    """No gradient flows into the target logits (drafts never update p)."""
    zp = rand(6, (4, 64))
    zq = rand(7, (4, 64))
    g = jax.grad(lambda z: jnp.sum(losses.lk_terms(z, zq)["kl"]))(zp)
    np.testing.assert_allclose(g, 0.0, atol=1e-9)


# ---------------------------------------------------------------------------
# adaptive λ schedule (paper eq. 5)
# ---------------------------------------------------------------------------

def test_lambda_schedule_limits():
    eta = jnp.float32(3.0)
    assert losses.adaptive_lambda(jnp.float32(0.0), eta) == pytest.approx(1.0)
    assert losses.adaptive_lambda(jnp.float32(1.0), eta) == pytest.approx(
        np.exp(-3.0), rel=1e-6
    )
    # monotone decreasing in alpha
    lams = [float(losses.adaptive_lambda(jnp.float32(a), eta)) for a in
            (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert all(a > b for a, b in zip(lams, lams[1:]))


def test_lambda_no_gradient_through_alpha():
    f = lambda a: losses.adaptive_lambda(a, jnp.float32(3.0))
    g = jax.grad(f)(jnp.float32(0.5))
    assert float(g) == 0.0  # stop-gradient


# ---------------------------------------------------------------------------
# head aggregation
# ---------------------------------------------------------------------------

def _loss_inputs(k=3, b=2, s=8, v=64, seed=0):
    zp = rand(seed, (k, b, s, v), 2.0)
    zq = rand(seed + 1, (k, b, s, v), 2.0)
    masks = jnp.ones((k, b, s))
    return zp, zq, masks


def test_gamma_weighting_prioritizes_head1():
    zp, zq, masks = _loss_inputs()
    w_kl = jnp.array([1.0, 0.0, 0.0, 0.0])
    # perturb only head 3's logits: with gamma → 0 the loss barely moves
    zq_pert = zq.at[2, :, :, :7].add(1.5)  # non-uniform: const shift is softmax-invariant
    for gamma, expect_sensitive in ((1.0, True), (0.05, False)):
        l0, _ = losses.draft_loss(zp, zq, masks, w_kl, 3.0, jnp.float32(gamma))
        l1, _ = losses.draft_loss(zp, zq_pert, masks, w_kl, 3.0, jnp.float32(gamma))
        delta = abs(float(l1 - l0))
        if expect_sensitive:
            assert delta > 1e-3
        else:
            assert delta < 1e-3


def test_loss_weights_select_objectives():
    zp, zq, masks = _loss_inputs(seed=10)
    t = losses.lk_terms(zp[0], zq[0])
    # pure-KL weights reproduce mean KL of head 1 when gamma ~ 0
    loss, metrics = losses.draft_loss(
        zp, zq, masks, jnp.array([1.0, 0.0, 0.0, 0.0]), 3.0, jnp.float32(1e-4)
    )
    np.testing.assert_allclose(float(loss), float(jnp.mean(t["kl"])), rtol=1e-3)
    assert metrics["alpha_heads"].shape == (3,)
    assert metrics["lambda_heads"].shape == (3,)


def test_masked_positions_excluded():
    zp, zq, masks = _loss_inputs(seed=20)
    # poison masked positions; loss must not change
    masks = masks.at[:, :, -2:].set(0.0)
    l0, _ = losses.draft_loss(zp, zq, masks, jnp.array([1.0, 0, 0, 0]), 3.0, 0.8)
    zq_poison = zq.at[:, :, -2:, :].add(37.0)
    l1, _ = losses.draft_loss(
        zp, zq_poison, masks, jnp.array([1.0, 0, 0, 0]), 3.0, 0.8
    )
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)


def test_hybrid_between_kl_and_tv():
    """L_LK^λ lies between pure KL and pure TV means (λ ∈ (0,1))."""
    zp, zq, masks = _loss_inputs(seed=30)
    def run(w):
        l, _ = losses.draft_loss(zp, zq, masks, jnp.array(w), 3.0, 0.8)
        return float(l)
    kl, tv, hyb = run([1, 0, 0, 0]), run([0, 1, 0, 0]), run([0, 0, 0, 1])
    assert min(kl, tv) - 1e-6 <= hyb <= max(kl, tv) + 1e-6
