import os
import sys

# Make `compile.*` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # Minimal images: the property-based modules importorskip hypothesis
    # themselves, so they collect as SKIPPED here (not ERROR) and every
    # hypothesis-free test still runs.
    settings = None
else:
    # CI-ish profile: deterministic, few examples (interpret-mode Pallas
    # is slow), no deadline (XLA compile pauses trip the default one).
    settings.register_profile(
        "lkspec", max_examples=12, deadline=None, derandomize=True
    )
    settings.load_profile("lkspec")
