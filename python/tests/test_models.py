"""L2 model/draft graph tests: shapes, KV-cache consistency (prefill +
verify == full forward), per-row positions, MoE routing, MTP wiring, and
a smoke train-step that must reduce loss / raise acceptance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import drafts as D
from compile import losses, train as T
from compile import model as M

KEY = jax.random.PRNGKey(0)


def small_cfg(**kw):
    base = dict(name="test", vocab=128, d_model=32, n_layers=3, n_heads=2, max_seq=48)
    base.update(kw)
    return M.TargetConfig(**base)


@pytest.mark.parametrize("experts", [0, 4])
def test_forward_shapes(experts):
    cfg = small_cfg(n_experts=experts)
    p = M.init_target(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    logits, feats = M.target_forward(p, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert feats.shape == (2, 16, 3 * cfg.d_model)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("experts", [0, 4])
def test_prefill_verify_equals_forward(experts):
    cfg = small_cfg(n_experts=experts)
    p = M.init_target(KEY, cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    full_logits, full_feats = M.target_forward(p, x, cfg)
    lg, kv, ft = M.target_prefill(p, x[:, :16], 16, cfg)
    np.testing.assert_allclose(lg[:, :16], full_logits[:, :16], rtol=3e-4, atol=3e-5)
    pos = jnp.array([16, 16], jnp.int32)
    lg2, kv2, ft2 = M.target_verify(p, kv, x[:, 16:24], pos, cfg)
    np.testing.assert_allclose(lg2, full_logits[:, 16:24], rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(ft2, full_feats[:, 16:24], rtol=3e-4, atol=3e-5)


def test_verify_per_row_positions():
    """Rows at different positions verify correctly in one call."""
    cfg = small_cfg()
    p = M.init_target(KEY, cfg)
    x = jax.random.randint(jax.random.PRNGKey(2), (2, 30), 0, cfg.vocab)
    _, kv, _ = M.target_prefill(p, x[:, :20], 20, cfg)
    lg, _, _ = M.target_verify(
        p, kv, x[:, 20:28], jnp.array([20, 12], jnp.int32), cfg
    )
    full0, _ = M.target_forward(p, x[:1, :28], cfg)
    np.testing.assert_allclose(lg[0], full0[0, 20:28], rtol=3e-4, atol=3e-5)
    seq1 = jnp.concatenate([x[1:2, :12], x[1:2, 20:28]], axis=1)
    full1, _ = M.target_forward(p, seq1, cfg)
    np.testing.assert_allclose(lg[1], full1[0, 12:20], rtol=3e-4, atol=3e-5)


def test_moe_top2_sparsity():
    """MoE gate must route each token to exactly 2 experts (weights sum 1)."""
    cfg = small_cfg(n_experts=4)
    lp = M.layer_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))
    gate_logits = x @ lp["moe"]["gate"]
    top_vals, _ = jax.lax.top_k(gate_logits, 2)
    w = jax.nn.softmax(top_vals, axis=-1)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-6)
    out = M.ffn_block(lp, x, cfg)
    assert out.shape == x.shape and jnp.isfinite(out).all()


def test_rope_positions_distinguish():
    cfg = small_cfg()
    x = jax.random.normal(KEY, (1, 2, 4, 8))
    a = M.rope(x, jnp.array([[0, 1, 2, 3]]), 10000.0)
    b = M.rope(x, jnp.array([[5, 6, 7, 8]]), 10000.0)
    assert not np.allclose(a, b)
    # norm-preserving (rotation)
    np.testing.assert_allclose(
        jnp.linalg.norm(a, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
    )


# ---------------------------------------------------------------------------
# drafts
# ---------------------------------------------------------------------------

def dcfg_for(arch, tcfg):
    return D.DraftConfig(arch=arch, target=tcfg, k_heads=4, draft_vocab=64)


@pytest.mark.parametrize("arch", ["eagle3", "mtp", "medusa", "mlp"])
def test_draft_unroll_shapes(arch):
    tcfg = small_cfg(n_experts=4 if arch == "mtp" else 0, has_mtp=arch == "mtp")
    dcfg = dcfg_for(arch, tcfg)
    tp = M.init_target(KEY, tcfg)
    dp = D.init_draft(jax.random.PRNGKey(5), dcfg)
    S, K = 12, 4
    toks = jax.random.randint(KEY, (2, S + K), 0, tcfg.vocab)
    _, feats = M.target_forward(tp, toks, tcfg)
    if arch == "eagle3":
        zq = D.draft_train_unroll(dp, tp, feats[:, :S], toks, dcfg)
        assert zq.shape == (K, 2, S, dcfg.draft_vocab)
    elif arch == "mtp":
        zq = D.draft_train_unroll(
            dp, tp, feats[:, :S, -tcfg.d_model :], toks, dcfg
        )
        assert zq.shape == (K, 2, S, tcfg.vocab)
    elif arch == "medusa":
        zq = D.medusa_propose(dp, feats[:, :S, -tcfg.d_model :], dcfg)
        assert zq.shape == (K, 2, S, tcfg.vocab)
    else:
        zq = D.mlp_train_unroll(dp, tp, feats[:, :S, -tcfg.d_model :], toks, dcfg)
        assert zq.shape == (K, 2, S, tcfg.vocab)
    assert jnp.isfinite(zq).all()


def test_eagle_extend_then_step_consistent():
    """A draft_step at position c must equal draft_extend's output for the
    same (token, hidden) pair appended at c."""
    tcfg = small_cfg()
    dcfg = dcfg_for("eagle3", tcfg)
    tp = M.init_target(KEY, tcfg)
    dp = D.init_draft(jax.random.PRNGKey(6), dcfg)
    S = 10
    toks = jax.random.randint(KEY, (1, S + 2), 0, tcfg.vocab)
    _, feats = M.target_forward(tp, toks, tcfg)
    dkv = jnp.zeros((2, 1, tcfg.n_heads, tcfg.max_seq, tcfg.head_dim))
    q, h, dkv1 = D.draft_extend(dp, tp, dkv, feats[:, :S], toks[:, 1 : S + 1], 0, dcfg)
    # one more step with the recurrent state
    q1, h1, _ = D.draft_step(
        dp, tp, dkv1, h[:, -1], toks[:, S + 1], jnp.array([S]), dcfg
    )
    assert q1.shape == (1, dcfg.draft_vocab)
    assert jnp.isfinite(q1).all() and jnp.isfinite(h1).all()


def test_mtp_init_from_target_matches_shapes():
    tcfg = small_cfg(n_experts=4, has_mtp=True)
    dcfg = dcfg_for("mtp", tcfg)
    tp = M.init_target(KEY, tcfg)
    restructured = D.init_mtp_from_target(tp)
    template = D.init_draft(jax.random.PRNGKey(7), dcfg)
    t_leaves = jax.tree_util.tree_leaves_with_path(restructured)
    d_leaves = jax.tree_util.tree_leaves_with_path(template)
    assert len(t_leaves) == len(d_leaves)
    key = lambda pv: jax.tree_util.keystr(pv[0])
    for (pa, va), (pb, vb) in zip(
        sorted(t_leaves, key=key), sorted(d_leaves, key=key)
    ):
        assert va.shape == vb.shape, (pa, va.shape, vb.shape)


# ---------------------------------------------------------------------------
# train steps learn
# ---------------------------------------------------------------------------

def test_target_train_step_reduces_loss():
    cfg = small_cfg()
    p = M.init_target(KEY, cfg)
    m = T.zeros_like_tree(p)
    v = T.zeros_like_tree(p)
    rng = np.random.default_rng(0)
    # learnable toy stream: next = (3*prev + 1) % vocab
    def batch():
        start = rng.integers(0, 128, size=(4, 1))
        seq = [start]
        for _ in range(17):
            seq.append((3 * seq[-1] + 1) % 128)
        return jnp.asarray(np.concatenate(seq, 1), jnp.int32)

    first = None
    for step in range(1, 25):
        p, m, v, metrics = T.target_train_step(
            p, m, v, jnp.int32(step), batch(), jnp.float32(3e-3), cfg
        )
        if first is None:
            first = float(metrics[0])
    assert float(metrics[0]) < first * 0.8, (first, float(metrics[0]))


@pytest.mark.parametrize("arch", ["eagle3", "medusa"])
def test_draft_train_step_raises_alpha(arch):
    tcfg = small_cfg()
    dcfg = dcfg_for(arch, tcfg)
    tp = M.init_target(KEY, tcfg)
    dp = D.init_draft(jax.random.PRNGKey(8), dcfg)
    m = T.zeros_like_tree(dp)
    v = T.zeros_like_tree(dp)
    vm = jnp.arange(64, dtype=jnp.int32) if arch == "eagle3" else None
    rng = np.random.default_rng(1)
    span = 12

    def batch():
        start = rng.integers(0, 128, size=(4, 1))
        seq = [start]
        for _ in range(span + dcfg.k_heads):
            seq.append((5 * seq[-1] + 3) % 128)
        return jnp.asarray(np.concatenate(seq, 1), jnp.int32)

    w = jnp.array([0.0, 0.0, 0.0, 1.0])  # hybrid LK^λ
    alpha0 = None
    for step in range(1, 31):
        dp, m, v, metrics = T.draft_train_step(
            tp, dp, m, v, jnp.int32(step), batch(), w, jnp.float32(3.0),
            jnp.float32(0.8), jnp.float32(2e-3), vm, dcfg, span,
        )
        if alpha0 is None:
            alpha0 = float(metrics[1])
    assert float(metrics[1]) > alpha0 + 0.02, (alpha0, float(metrics[1]))
    # metric layout: [loss, mean_alpha, alpha*K, lambda*K]
    assert metrics.shape == (2 + 2 * dcfg.k_heads,)
    lam = metrics[2 + dcfg.k_heads :]
    assert ((lam > 0) & (lam <= 1.0)).all()
