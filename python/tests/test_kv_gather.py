"""Parity tests for the cross-bucket KV row gather entry
(`kv_gather_rows_b{Bsrc}x{Bdst}` / `dkv_gather_rows_b{Bsrc}x{Bdst}`).

The lowered entry is `verify_device.gather_rows` — a single jnp.take
along the batch axis. The reference here is a plain python loop copying
row slices one at a time, the same strided semantics as the Rust host
fallback `server::kv::gather_rows`. The two must agree BIT-FOR-BIT:
migration sits on the engine's exactness path (a gathered row later
verifies tokens), so "close" is not good enough.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import verify_device as VD
from compile.aot import SERVE_BATCHES

# Small-but-nontrivial KV dims: [L, 2, B, H, S, Dh] target layout.
L, H, S, DH = 2, 3, 7, 5


def host_gather(kv: np.ndarray, row_map, batch_axis: int) -> np.ndarray:
    """Row-at-a-time reference: out row i <- kv row row_map[i]."""
    out = []
    for r in row_map:
        out.append(np.take(kv, [r], axis=batch_axis))
    return np.concatenate(out, axis=batch_axis)


def rand_kv(shape, seed):
    # Full-range f32 bit patterns (denormal-free) so bit-equality is a
    # real check, not a round-number coincidence.
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * 1e3).astype(np.float32)


def bucket_pairs():
    return [
        (bs, bd) for bs in SERVE_BATCHES for bd in SERVE_BATCHES if bs != bd
    ]


@pytest.mark.parametrize("bsrc,bdst", bucket_pairs())
def test_target_kv_gather_matches_host_loop(bsrc, bdst):
    kv = rand_kv((L, 2, bsrc, H, S, DH), seed=bsrc * 10 + bdst)
    # Downshift packs a subset; upshift REPEATS row 0 into the padding
    # clones — exactly the row_maps the scheduler builds.
    row_map = [i % bsrc for i in range(bdst)]
    got = np.asarray(
        jax.jit(VD.gather_rows, static_argnums=2)(
            jnp.asarray(kv), jnp.asarray(row_map, jnp.int32), 2
        )
    )
    want = host_gather(kv, row_map, batch_axis=2)
    assert got.shape == (L, 2, bdst, H, S, DH)
    assert got.dtype == np.float32
    np.testing.assert_array_equal(got, want)  # bit-for-bit, no tolerance


@pytest.mark.parametrize("bsrc,bdst", bucket_pairs())
def test_draft_kv_gather_matches_host_loop(bsrc, bdst):
    dkv = rand_kv((2, bsrc, H, S, DH), seed=100 + bsrc * 10 + bdst)
    row_map = [min(i, bsrc - 1) for i in range(bdst)]
    got = np.asarray(
        jax.jit(VD.gather_rows, static_argnums=2)(
            jnp.asarray(dkv), jnp.asarray(row_map, jnp.int32), 1
        )
    )
    want = host_gather(dkv, row_map, batch_axis=1)
    assert got.shape == (2, bdst, H, S, DH)
    np.testing.assert_array_equal(got, want)


def test_gather_permutation_and_clone_semantics():
    """Permutations relocate rows exactly; repeated sources alias."""
    kv = rand_kv((L, 2, 4, H, S, DH), seed=7)
    perm = [3, 1, 0, 2]
    got = np.asarray(VD.gather_rows(jnp.asarray(kv), jnp.asarray(perm, jnp.int32), 2))
    for dst, src in enumerate(perm):
        np.testing.assert_array_equal(got[:, :, dst], kv[:, :, src])
    # Padding clones: every dst row mapping to the same source is the
    # same bytes (the scheduler's upshift fills pad rows with row 0).
    clones = np.asarray(
        VD.gather_rows(jnp.asarray(kv), jnp.asarray([2, 2, 2, 2], jnp.int32), 2)
    )
    for dst in range(4):
        np.testing.assert_array_equal(clones[:, :, dst], kv[:, :, 2])
