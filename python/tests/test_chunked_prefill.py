"""Chunked-prefill parity: composing fixed-length `target_verify` chunks
at pos = 0, C, 2C, ... over a zero-initialized KV must reproduce
whole-prompt `target_prefill` — KV, features, final-position logits, and
the greedy first token. This is the correctness keystone for the serving
`prefill_chunk_b{B}` entries (DESIGN.md §11): the chunk forward is the
verify forward, so the causal mask `(jpos <= qpos) & (jpos < kv_len)`
and RoPE positions `pos + arange(s)` compose to exactly the whole-prompt
arithmetic for every computed position.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

KEY = jax.random.PRNGKey(0)


def small_cfg(**kw):
    base = dict(name="test", vocab=128, d_model=32, n_layers=3, n_heads=2, max_seq=64)
    base.update(kw)
    return M.TargetConfig(**base)


def zero_kv(cfg, b):
    # Stacked serving layout [L, 2, B, H, Smax, Dh] — matches the
    # kv_spec the AOT entries carry executable-to-executable.
    return jnp.zeros(
        (cfg.n_layers, 2, b, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    )


def run_chunks(p, tokens, chunk, cfg):
    """Drive prefill as fixed-size verify chunks; returns the last
    chunk's logits plus the carried kv/feats, mirroring the engine's
    PendingPrefill accumulation."""
    b, sp = tokens.shape
    assert sp % chunk == 0
    kv = zero_kv(cfg, b)
    feats = []
    logits = None
    for j in range(sp // chunk):
        pos = jnp.full((b,), j * chunk, dtype=jnp.int32)
        logits, kv, ft = M.target_verify(
            p, kv, tokens[:, j * chunk : (j + 1) * chunk], pos, cfg
        )
        feats.append(ft)
    return logits, kv, jnp.concatenate(feats, axis=1)


@pytest.mark.parametrize("experts", [0, 4])
@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_equals_whole_prompt(experts, chunk):
    cfg = small_cfg(n_experts=experts)
    p = M.init_target(KEY, cfg)
    sp = 32
    x = jax.random.randint(jax.random.PRNGKey(1), (2, sp), 0, cfg.vocab)

    lg_whole, kv_whole, ft_whole = M.target_prefill(p, x, sp, cfg)
    lg_last, kv_chunk, ft_chunk = run_chunks(p, x, chunk, cfg)

    # KV parity over the written region (beyond sp both are zeros).
    np.testing.assert_allclose(
        kv_chunk[:, :, :, :, :sp], kv_whole[:, :, :, :, :sp], atol=1e-5
    )

    # Feature-carry parity (the EAGLE-style draft conditioning input).
    np.testing.assert_allclose(ft_chunk, ft_whole, atol=1e-5)

    # The last chunk's final-position logits are what the engine samples
    # the first token from; they must match the whole-prompt logits at
    # position sp-1 — and the greedy argmax must match exactly.
    np.testing.assert_allclose(lg_last[:, -1], lg_whole[:, -1], atol=1e-4)
    assert (jnp.argmax(lg_last[:, -1], -1) == jnp.argmax(lg_whole[:, -1], -1)).all()


def test_skipped_prefix_chunks_resume_identically():
    """A radix prefix hit lets the engine skip already-computed chunks
    and seed the carry from a cached KV snapshot. Model that: compute
    chunks 0..j from one run, resume j.. with the same carried KV, and
    require the result to match the uninterrupted composition."""
    cfg = small_cfg()
    p = M.init_target(KEY, cfg)
    sp, chunk = 32, 8
    x = jax.random.randint(jax.random.PRNGKey(2), (1, sp), 0, cfg.vocab)

    lg_full, kv_full, ft_full = run_chunks(p, x, chunk, cfg)

    # "Cached" carry: first two chunks computed by an earlier session.
    kv = zero_kv(cfg, 1)
    for j in range(2):
        pos = jnp.full((1,), j * chunk, dtype=jnp.int32)
        _, kv, _ = M.target_verify(p, kv, x[:, j * chunk : (j + 1) * chunk], pos, cfg)
    # Resume from chunk 2 over the cached carry.
    lg = None
    for j in range(2, sp // chunk):
        pos = jnp.full((1,), j * chunk, dtype=jnp.int32)
        lg, kv, _ = M.target_verify(p, kv, x[:, j * chunk : (j + 1) * chunk], pos, cfg)

    np.testing.assert_allclose(
        kv[:, :, :, :, :sp], kv_full[:, :, :, :, :sp], atol=1e-5
    )
    np.testing.assert_allclose(lg[:, -1], lg_full[:, -1], atol=1e-4)


def test_decode_after_chunked_prefill_matches():
    """End-to-end: a verify round launched off a chunked-prefill carry
    produces the same logits as one launched off whole-prompt prefill —
    greedy decode downstream is therefore token-identical."""
    cfg = small_cfg()
    p = M.init_target(KEY, cfg)
    sp, chunk, t = 32, 16, 8
    x = jax.random.randint(jax.random.PRNGKey(3), (1, sp + t), 0, cfg.vocab)

    _, kv_w, _ = M.target_prefill(p, x[:, :sp], sp, cfg)
    _, kv_c, _ = run_chunks(p, x[:, :sp], chunk, cfg)

    pos = jnp.full((1,), sp, dtype=jnp.int32)
    lg_w, _, _ = M.target_verify(p, kv_w, x[:, sp:], pos, cfg)
    lg_c, _, _ = M.target_verify(p, kv_c, x[:, sp:], pos, cfg)
    np.testing.assert_allclose(lg_c, lg_w, atol=1e-4)
    assert (jnp.argmax(lg_c, -1) == jnp.argmax(lg_w, -1)).all()
