"""Recurrent (EAGLE-3) tree drafting: the level-parallel expansion
(`drafts.draft_tree_step` / `drafts.draft_tree_propose`), the draft-side
path splice (`drafts.dkv_path_gather`) and the per-node candidate
sampling (`verify_device.tree_child_sample` / `tree_root_sample`) — the
graphs behind the `tree_step_b{B}` / `propose_tree_sample_b{B}` /
`dkv_path_gather_b{B}` / `extend_tree_sample_b{B}` AOT entries.

The two contracts under test:

  * CHAIN DEGENERACY — a single-chain topology through the tree graphs
    reproduces the chained `draft_step` path: same distributions, same
    hiddens, same draft-KV entries (the recurrent analog of the PR-3
    medusa-tree property, here at the graph level);
  * HOST/DEVICE PROPOSAL PARITY — the one-graph device expansion
    (`draft_tree_propose`) emits exactly the candidates the engine's
    level-by-level host loop samples from the same uniforms (token-exact:
    both consume the shared `tree_step` distributions through identical
    per-element selection rules).

Deliberately hypothesis-free so the suite runs on minimal images.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import drafts as D
from compile import model as M
from compile import verify_device as VD

# Tiny config: 2-layer target, 1-block eagle3 draft, truncated vocab.
TCFG = M.TargetConfig(
    name="tiny", vocab=64, d_model=16, n_layers=2, n_heads=2, max_seq=48
)
DCFG = D.DraftConfig(arch="eagle3", target=TCFG, k_heads=4, draft_vocab=24)

# BFS node-parent arrays (TreeSpec contract).
CHAIN3 = np.array([-1, 0, 1], np.int32)
TREE_2X2 = np.array([-1, -1, 0, 0, 1, 1], np.int32)
TREE_MIXED = np.array([-1, -1, -1, 0, 1], np.int32)


def _setup(b=2, prompt=6, seed=0):
    """Params + a bootstrapped draft state (dkv with a committed prompt
    prefix, per-row q1 logits and conditioning hidden at position c-1)."""
    key = jax.random.PRNGKey(seed)
    kt, kd, kf, ktok = jax.random.split(key, 4)
    tp = M.init_target(kt, TCFG)
    dp = D.init_draft(kd, DCFG)
    vocab_map = jnp.sort(
        jax.random.choice(kf, TCFG.vocab, (DCFG.draft_vocab,), replace=False)
    ).astype(jnp.int32)
    dkv0 = jnp.zeros(
        (2, b, TCFG.n_heads, TCFG.max_seq, TCFG.head_dim), jnp.float32
    )
    feats = jax.random.normal(kf, (b, prompt, DCFG.fuse_dim)) * 0.3
    tnext = jax.random.randint(ktok, (b, prompt), 0, TCFG.vocab)
    qlog, h, dkv = D.draft_extend(dp, tp, dkv0, feats, tnext, 0, DCFG)
    c = prompt  # committed length
    q1 = qlog[:, c - 1]  # [B, Vd] first-draft logits
    h_prev = h[:, c - 1]  # [B, d]
    return tp, dp, vocab_map, dkv, q1, h_prev, c


def _levels(parents):
    lv = []
    for i, p in enumerate(parents):
        lv.append(0 if p < 0 else lv[p] + 1)
    return np.array(lv, np.int32)


def _ranks(parents):
    out, last, r = [], None, 0
    for p in parents:
        r = r + 1 if p == last else 0
        last = p
        out.append(r)
    return np.array(out, np.int32)


# ---------------------------------------------------------------------------
# chain degeneracy of the level-parallel step
# ---------------------------------------------------------------------------

def test_tree_step_chain_matches_draft_step():
    """A chain topology through `draft_tree_step` reproduces the chained
    `draft_step` recurrence: same per-node distributions and hiddens,
    same draft-KV entries at the same slots."""
    tp, dp, _, dkv, q1, h_prev, c = _setup()
    b = q1.shape[0]
    n = 3
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, TCFG.vocab, (b, n)), jnp.int32)
    pos = jnp.full((b,), c, jnp.int32)

    # --- chained reference: draft_step at pos c, c+1 (k-1 = 2 calls) ---
    q_ref, h_ref, dkv_ref = [], [], dkv
    h_cur = h_prev
    for i in range(n - 1):
        qlog, h_cur, dkv_ref = D.draft_step(
            dp, tp, dkv_ref, h_cur, toks[:, i], jnp.full((b,), c + i), DCFG
        )
        q_ref.append(qlog)
        h_ref.append(h_cur)

    # --- level-parallel: depth-1 calls over the full block --------------
    parents = jnp.asarray(CHAIN3)
    h_all = jnp.zeros((b, n, TCFG.d_model))
    dkv_t = dkv
    outs = []
    for _ in range(n - 1):
        qlog, h_all, dkv_t = D.draft_tree_step(
            dp, tp, dkv_t, h_prev, h_all, toks, pos, parents, DCFG
        )
        outs.append(qlog)

    for i in range(n - 1):
        np.testing.assert_allclose(
            outs[i][:, i], q_ref[i], rtol=1e-5, atol=1e-5,
            err_msg=f"node {i} distribution diverged from the chain",
        )
    np.testing.assert_allclose(
        h_all[:, n - 2], h_ref[-1], rtol=1e-5, atol=1e-5
    )
    # draft-KV entries the chain wrote (slots c, c+1) must match.
    np.testing.assert_allclose(
        dkv_t[:, :, :, c : c + n - 1],
        dkv_ref[:, :, :, c : c + n - 1],
        rtol=1e-5, atol=1e-5,
        err_msg="tree block KV entries diverged from the chained writes",
    )
    # committed prefix untouched
    np.testing.assert_array_equal(dkv_t[:, :, :, :c], dkv[:, :, :, :c])


def test_tree_step_padding_slots_inert():
    """Self-parent padding slots change nothing for the real nodes."""
    tp, dp, _, dkv, q1, h_prev, c = _setup()
    b = q1.shape[0]
    rng = np.random.default_rng(3)
    toks3 = jnp.asarray(rng.integers(0, TCFG.vocab, (b, 3)), jnp.int32)
    pos = jnp.full((b,), c, jnp.int32)
    # exact-size block
    q_a, h_a, _ = D.draft_tree_step(
        dp, tp, dkv, h_prev, jnp.zeros((b, 3, TCFG.d_model)),
        toks3, pos, jnp.asarray(CHAIN3), DCFG,
    )
    # padded to 5 slots (self-parents, junk tokens)
    pad_parents = jnp.asarray(np.array([-1, 0, 1, 3, 4], np.int32))
    toks5 = jnp.concatenate(
        [toks3, jnp.full((b, 2), 11, jnp.int32)], axis=1
    )
    q_b, h_b, _ = D.draft_tree_step(
        dp, tp, dkv, h_prev, jnp.zeros((b, 5, TCFG.d_model)),
        toks5, pos, pad_parents, DCFG,
    )
    np.testing.assert_allclose(q_b[:, :3], q_a, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_b[:, :3], h_a, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# the draft-side path splice
# ---------------------------------------------------------------------------

def test_dkv_path_gather_splices_rows():
    rng = np.random.default_rng(5)
    b, h, s, dh = 2, 2, 12, 4
    dkv = rng.normal(size=(2, b, h, s, dh)).astype(np.float32)
    kq = 3
    sel = np.array([[7, 9, 10], [4, 4, 6]], np.int32)
    dst0 = np.array([5, 3], np.int32)
    out = np.array(D.dkv_path_gather(
        jnp.asarray(dkv), jnp.asarray(sel), jnp.asarray(dst0)
    ))
    want = dkv.copy()
    for bi in range(b):
        for t in range(kq):
            want[:, bi, :, dst0[bi] + t] = dkv[:, bi, :, sel[bi, t]]
    np.testing.assert_array_equal(out, want)


# ---------------------------------------------------------------------------
# device expansion == host level-by-level loop (token-exact)
# ---------------------------------------------------------------------------

def _host_propose_tree(tp, dp, vocab_map, dkv, q1, h_prev, c, parents, u, mode):
    """Transcription of the Rust host loop (RecurrentTree::propose_tree):
    level 0 sampled from q1 compact + vocab-map, one `draft_tree_step`
    per deeper level, children sampled from the parent's compact
    distribution — the same selection formulations as the device graph.
    """
    b = q1.shape[0]
    n = len(parents)
    levels = _levels(parents)
    ranks = _ranks(parents)
    depth = int(levels.max()) + 1
    temp = jnp.float32(1.0)

    def sample(logits_c, ui, rank):
        qc = VD.temp_softmax(logits_c, temp)  # [B, Vd]
        if mode == VD.MODE_STOCHASTIC:
            tok_c = VD.categorical_from_uniform(qc, ui)
        else:
            tok_c = VD.kth_argmax(qc, jnp.int32(rank), n)
        q_full = (
            jnp.zeros((b, TCFG.vocab), qc.dtype).at[:, vocab_map].set(qc)
        )
        return jnp.take(vocab_map, tok_c).astype(jnp.int32), q_full

    toks = np.zeros((b, n), np.int32)
    qs = np.zeros((b, n, TCFG.vocab), np.float32)
    for i in range(n):
        if levels[i] == 0:
            t_i, q_i = sample(q1, u[:, i], ranks[i])
            toks[:, i] = np.array(t_i)
            qs[:, i] = np.array(q_i)
    h_all = jnp.zeros((b, n, TCFG.d_model))
    dkv_c = dkv
    pos = jnp.full((b,), c, jnp.int32)
    for lvl in range(depth - 1):
        qlog, h_all, dkv_c = D.draft_tree_step(
            dp, tp, dkv_c, h_prev, h_all, jnp.asarray(toks), pos,
            jnp.asarray(parents), DCFG,
        )
        for i in range(n):
            if levels[i] == lvl + 1:
                t_i, q_i = sample(qlog[:, parents[i]], u[:, i], ranks[i])
                toks[:, i] = np.array(t_i)
                qs[:, i] = np.array(q_i)
    return toks, qs, dkv_c


def test_tree_propose_device_matches_host_loop():
    """`draft_tree_propose` (the one-graph device expansion) emits
    exactly the host loop's candidates from the same uniforms, in both
    stochastic and greedy modes, on branching and chain topologies."""
    tp, dp, vocab_map, dkv, q1, h_prev, c = _setup()
    b = q1.shape[0]
    rng = np.random.default_rng(11)
    for parents in (TREE_2X2, TREE_MIXED, CHAIN3):
        n = len(parents)
        u = rng.uniform(size=(b, n)).astype(np.float32)
        for mode in (VD.MODE_STOCHASTIC, VD.MODE_GREEDY):
            host_toks, host_qs, _ = _host_propose_tree(
                tp, dp, vocab_map, dkv, q1, h_prev, c, parents,
                jnp.asarray(u), mode,
            )
            # device inputs: node 0 pre-sampled by the previous extend
            # (tok0/q0) — here the host's own node-0 result.
            qc0 = VD.temp_softmax(q1, jnp.float32(1.0))
            q0_full = (
                jnp.zeros((b, TCFG.vocab), qc0.dtype)
                .at[:, vocab_map].set(qc0)
            )
            tok0 = jnp.asarray(host_toks[:, 0])
            dev_toks, dev_qs, _ = D.draft_tree_propose(
                dp, tp, dkv, h_prev, tok0, q0_full, jnp.asarray(u),
                jnp.asarray(parents), jnp.asarray(_ranks(parents)),
                jnp.full((b,), c, jnp.int32), jnp.float32(1.0),
                jnp.int32(mode), DCFG, vocab_map, TCFG.vocab, n,
            )
            np.testing.assert_array_equal(
                np.array(dev_toks), host_toks,
                err_msg=f"parents={list(parents)} mode={mode}: candidates"
                " diverged between device graph and host loop",
            )
            for i in range(n):
                np.testing.assert_allclose(
                    np.array(dev_qs[i]), host_qs[:, i], rtol=1e-6,
                    atol=1e-6,
                    err_msg=f"node {i} q diverged (mode={mode})",
                )


def test_tree_root_sample_full_equals_compact():
    """Selection over the SCATTERED full-vocab q equals compact-then-map
    (the sorted vocab map preserves cumsum and rank order) — what lets
    the device path sample level-0 siblings from the resident q0."""
    rng = np.random.default_rng(13)
    b, vd, v = 3, 8, 32
    vocab_map = jnp.asarray(np.sort(rng.choice(v, vd, replace=False)), jnp.int32)
    logits = jnp.asarray(rng.normal(size=(b, vd)), jnp.float32)
    qc = VD.temp_softmax(logits, jnp.float32(1.0))
    q_full = jnp.zeros((b, v), qc.dtype).at[:, vocab_map].set(qc)
    u = jnp.asarray(rng.uniform(size=(b,)), jnp.float32)
    for rank in range(3):
        for mode in (VD.MODE_STOCHASTIC, VD.MODE_GREEDY):
            full = VD.tree_root_sample(q_full, u, jnp.int32(rank), jnp.int32(mode), 4)
            if mode == VD.MODE_STOCHASTIC:
                compact = VD.categorical_from_uniform(qc, u)
            else:
                compact = VD.kth_argmax(qc, jnp.int32(rank), 4)
            np.testing.assert_array_equal(
                np.array(full), np.array(jnp.take(vocab_map, compact))
            )


# ---------------------------------------------------------------------------
# the device advance's feats linearization
# ---------------------------------------------------------------------------

def test_feats_path_linearization():
    """`extend_tree_sample`'s in-graph gather: blk maps chain row t to
    tree block slot, so the linearized feats row t is the feature after
    the t-th accepted token — identity blk is a no-op (chain rounds)."""
    rng = np.random.default_rng(17)
    b, t, f = 2, 8, 12
    feats = jnp.asarray(rng.normal(size=(b, t, f)), jnp.float32)
    ident = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    out = jnp.take_along_axis(feats, ident[:, :, None], axis=1)
    np.testing.assert_array_equal(np.array(out), np.array(feats))
    blk = np.array([[0, 2, 5, 5, 5, 5, 5, 5], [0, 1, 3, 4, 4, 4, 4, 4]], np.int32)
    out = np.array(
        jnp.take_along_axis(feats, jnp.asarray(blk)[:, :, None], axis=1)
    )
    for bi in range(b):
        for tt in range(t):
            np.testing.assert_array_equal(out[bi, tt], np.array(feats)[bi, blk[bi, tt]])
