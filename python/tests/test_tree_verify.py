"""Multi-candidate (tree) verify correctness: the blocked Pallas walk
(`kernels.fused_verify.tree_verify_row`) against the jnp serving graph
(`compile.verify_device._tree_verify_row`) against a literal
transcription of the Rust host path (`spec::sampling::verify_tree_lazy`)
— the three implementations whose agreement the engine's tree
host/device parity rests on — plus the topology helpers, the
tree-attention forward and the in-graph candidate sampling.

Deliberately hypothesis-free so the suite runs on minimal images; the
randomized sweeps are seeded and exhaustive over (topology, mode).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import verify_device as VD
from compile.kernels import fused_verify

# BFS-ordered node-parent arrays (the TreeSpec contract: non-decreasing,
# parents[i] < i, -1 = root child).
TREES = {
    "2x2": [-1, -1, 0, 0, 1, 1],
    "chain7": [-1, 0, 1, 2, 3, 4, 5],
    "mixed": [-1, -1, -1, 0, 1],
    "single": [-1],
}


# ---------------------------------------------------------------------------
# host-path mirror (keep in lockstep with spec::sampling::verify_tree_lazy)
# ---------------------------------------------------------------------------

def _host_threshold_select(r, t):
    """Mirror of `spec::sampling::threshold_select`."""
    c = 0.0
    last = None
    for i, v in enumerate(r):
        if v > 0:
            last = i
        c += v
        if c >= t:
            return i
    return last if last is not None else len(r) - 1


def _host_tree_verify(logits, q, drafted, parents, u_acc, u_samp, temp, mode, n_active):
    """Mirror of `spec::sampling::verify_tree_lazy` (the Rust host walk)."""
    n1, _ = logits.shape
    n = len(parents)

    def softmax_t(z, t):
        z = (z - z.max()) * (1.0 / max(t, 1e-3))
        e = np.exp(z)
        return e / e.sum()

    p = np.stack([softmax_t(logits[j], temp) for j in range(n1)])
    cur = -1
    r = p[0].copy()
    z, zone = 1.0, True
    path = []
    i = 0
    while i < min(n, n_active):
        par = parents[i]
        if par > cur:
            break  # BFS order: no children of cur remain
        if par < cur:
            i += 1
            continue
        x = drafted[i]
        z_eff = 1.0 if zone else z
        qi = q[i]
        # an emptied residual (z == 0) rejects every remaining candidate
        if mode == VD.MODE_GREEDY:
            ok = int(np.argmax(p[cur + 1])) == x
        elif mode == VD.MODE_STOCHASTIC:
            ok = u_acc[i] < (
                min(1.0, r[x] / (z_eff * qi[x]))
                if qi[x] > 0 and z_eff > 0
                else 0.0
            )
        else:  # greedy-draft: q treated as 1
            ok = z_eff > 0 and u_acc[i] < min(1.0, r[x] / z_eff)
        if ok:
            cur = i
            path.append(i)
            r = p[i + 1].copy()
            zone = True
        else:
            r = np.maximum(r - z_eff * qi, 0.0)
            z = float(r.sum())
            zone = False
        i += 1
    z_eff = 1.0 if zone else z
    if mode == VD.MODE_GREEDY:
        tok = int(np.argmax(p[cur + 1]))
    elif z_eff > 0:
        tok = _host_threshold_select(r, u_samp * z_eff)
    else:
        tok = _host_threshold_select(p[cur + 1], u_samp)
    return len(path), path, tok, cur + 1


def _rand_case(rng, parents, v=64):
    n = len(parents)
    logits = rng.normal(0, 2, (n + 1, v)).astype(np.float32)
    q = np.asarray(
        jax.nn.softmax(jnp.asarray(rng.normal(0, 2, (n, v)), jnp.float32))
    )
    drafted = rng.integers(0, v, n).astype(np.int32)
    u_acc = rng.random(n).astype(np.float32)
    u_samp = np.float32(rng.random())
    return logits, q, drafted, u_acc, u_samp


# ---------------------------------------------------------------------------
# three-way agreement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tree", sorted(TREES))
@pytest.mark.parametrize("mode", [0, 1, 2])
def test_graph_matches_host_walk(tree, mode):
    parents = TREES[tree]
    n = len(parents)
    rng = np.random.default_rng(500 + 10 * mode + n)
    for trial in range(25):
        temp = float(rng.choice([0.7, 1.0, 1.5]))
        n_active = int(rng.integers(1, n + 1))
        logits, q, drafted, u_acc, u_samp = _rand_case(rng, parents)
        np_, path, out, stop = VD._tree_verify_row(
            jnp.asarray(logits), jnp.asarray(q), jnp.asarray(drafted),
            jnp.asarray(parents, jnp.int32), jnp.asarray(u_acc),
            jnp.asarray(u_samp), jnp.float32(temp), jnp.int32(mode),
            jnp.int32(n_active),
        )
        hn, hpath, htok, hstop = _host_tree_verify(
            logits.astype(np.float64), q.astype(np.float64), drafted,
            parents, u_acc, float(u_samp), temp, mode, n_active,
        )
        assert int(np_) == hn, (tree, trial)
        assert list(np.asarray(path)[:hn]) == hpath, (tree, trial)
        assert int(np.asarray(out)[hn]) == htok, (tree, trial)
        assert int(stop) == hstop, (tree, trial)
        # echo layout: accepted candidates then the emission
        np.testing.assert_array_equal(
            np.asarray(out)[:hn], drafted[np.asarray(hpath, int)]
        )


@pytest.mark.parametrize("tree", ["2x2", "chain7", "mixed"])
@pytest.mark.parametrize("vb", [16, 64])
def test_kernel_matches_graph(tree, vb):
    parents = TREES[tree]
    n = len(parents)
    rng = np.random.default_rng(700 + n + vb)
    for mode in (0, 1, 2):
        for trial in range(6):
            temp = float(rng.choice([0.7, 1.0, 1.5]))
            n_active = int(rng.integers(1, n + 1))
            logits, q, drafted, u_acc, u_samp = _rand_case(rng, parents)
            args = (
                jnp.asarray(logits), jnp.asarray(q), jnp.asarray(drafted),
                jnp.asarray(parents, jnp.int32), jnp.asarray(u_acc),
                jnp.asarray(u_samp), jnp.float32(temp), jnp.int32(mode),
                jnp.int32(n_active),
            )
            ng, pg, outg, sbg = VD._tree_verify_row(*args)
            nk, pk, outk, sbk = fused_verify.tree_verify_row(*args, vocab_block=vb)
            assert int(nk) == int(ng), (tree, mode, trial)
            np.testing.assert_array_equal(np.asarray(pk), np.asarray(pg))
            np.testing.assert_array_equal(
                np.asarray(outk)[: int(ng) + 1], np.asarray(outg)[: int(ng) + 1]
            )
            assert int(sbk) == int(sbg)


def test_chain_topology_degenerates_to_chain_verify():
    """A chain TreeSpec through the tree rule == the chain `_verify_row`
    (same uniforms -> same accepted prefix, same emitted token)."""
    k1, v = 8, 64
    k = k1 - 1
    parents = np.arange(-1, k - 1, dtype=np.int32)
    rng = np.random.default_rng(11)
    for mode in (0, 1, 2):
        for trial in range(20):
            temp = float(rng.choice([0.7, 1.0, 1.5]))
            k_active = int(rng.integers(1, k + 1))
            logits, q, drafted, u_acc, u_samp = _rand_case(rng, list(parents))
            chain_args = (
                jnp.asarray(logits), jnp.asarray(q), jnp.asarray(drafted),
                jnp.asarray(u_acc), jnp.asarray(u_samp), jnp.float32(temp),
                jnp.int32(mode), jnp.int32(k_active),
            )
            na, toks = VD._verify_row(*chain_args)
            nt, _, outt, _ = VD._tree_verify_row(
                jnp.asarray(logits), jnp.asarray(q), jnp.asarray(drafted),
                jnp.asarray(parents), jnp.asarray(u_acc), jnp.asarray(u_samp),
                jnp.float32(temp), jnp.int32(mode), jnp.int32(k_active),
            )
            assert int(na) == int(nt), (mode, trial)
            np.testing.assert_array_equal(
                np.asarray(toks)[: int(na) + 1], np.asarray(outt)[: int(na) + 1]
            )


# ---------------------------------------------------------------------------
# exactness
# ---------------------------------------------------------------------------

def test_two_candidate_tree_preserves_target():
    """The multi-draft rule with two i.i.d. candidates emits exactly p
    (SpecInfer/MCSD recursive-rejection invariant) — the tree analog of
    the chain Leviathan test."""
    rng = np.random.default_rng(21)
    v = 16
    logits = rng.normal(0, 2, (3, v)).astype(np.float32)
    logits[2] = logits[1]  # bonus rows never counted below
    q0 = np.asarray(
        jax.nn.softmax(jnp.asarray(rng.normal(0, 2, (v,)), jnp.float32))
    ).astype(np.float64)

    def p_of(z):
        e = np.exp(z - z.max())
        return e / e.sum()

    p = p_of(logits[0].astype(np.float64))
    parents = jnp.asarray([-1, -1], jnp.int32)
    nsamp = 40_000
    drafted = np.stack(
        [
            [_host_threshold_select(q0, u1), _host_threshold_select(q0, u2)]
            for u1, u2 in rng.random((nsamp, 2))
        ]
    ).astype(np.int32)
    np_, path, out, _ = VD.tree_verify(
        jnp.broadcast_to(jnp.asarray(logits), (nsamp, 3, v)),
        jnp.broadcast_to(jnp.asarray(q0, jnp.float32)[None, None], (nsamp, 2, v)),
        jnp.asarray(drafted),
        parents,
        jnp.asarray(rng.random((nsamp, 2)), jnp.float32),
        jnp.asarray(rng.random(nsamp), jnp.float32),
        jnp.float32(1.0), jnp.int32(1), jnp.int32(2),
    )
    emitted = np.asarray(out)[:, 0]  # first accepted candidate or replacement
    counts = np.bincount(emitted, minlength=v) / nsamp
    np.testing.assert_allclose(counts, p, atol=0.012)
    # with two candidates some rounds must accept the SECOND sibling
    first_nodes = np.asarray(path)[:, 0]
    accepted = np.asarray(np_) > 0
    assert (first_nodes[accepted] == 1).any()


@pytest.mark.parametrize("mode", [1, 2])
def test_empty_residual_rejects_remaining_siblings(mode):
    """Once rejected siblings cover the whole target row (z == 0), the
    remaining candidates must be rejected — no 0/0 acceptance — and the
    emission falls back to the pristine row. Pins graph == kernel ==
    host mirror on the edge the clamped/NaN arithmetic has to agree on
    (the fixture matches `tree_verify_empty_residual_rejects_remaining_
    siblings` in spec::sampling)."""
    v = 4
    parents = [-1, -1, -1]
    logits = np.log(
        np.asarray(
            [
                [0.5, 0.25, 0.25, 1.0],
                [0.25, 0.25, 0.25, 0.25],
                [0.25, 0.25, 0.25, 0.25],
                [0.25, 0.25, 0.25, 0.25],
            ],
            np.float32,
        )
    )
    logits[0, 3] = -1e4  # exp underflows to an EXACT zero in f32 and f64
    q = np.asarray(
        [[1, 0, 0, 0], [0, 0.5, 0.5, 0], [0, 1, 0, 0]], np.float32
    )
    drafted = np.asarray([0, 3, 1], np.int32)
    u_acc = np.asarray([0.9, 0.999, 0.0], np.float32)
    u_samp = np.float32(0.6)
    args = (
        jnp.asarray(logits), jnp.asarray(q), jnp.asarray(drafted),
        jnp.asarray(parents, jnp.int32), jnp.asarray(u_acc),
        jnp.asarray(u_samp), jnp.float32(1.0), jnp.int32(mode), jnp.int32(3),
    )
    ng, pg, outg, _ = VD._tree_verify_row(*args)
    nk, _, outk, _ = fused_verify.tree_verify_row(*args, vocab_block=v)
    hn, _, htok, _ = _host_tree_verify(
        logits.astype(np.float64), q.astype(np.float64), drafted,
        parents, u_acc, float(u_samp), 1.0, mode, 3,
    )
    assert int(ng) == 0 and int(nk) == 0 and hn == 0
    # all three fall back to the pristine root row's inverse CDF
    assert int(np.asarray(outg)[0]) == htok == int(np.asarray(outk)[0])


# ---------------------------------------------------------------------------
# topology + attention + sampling helpers
# ---------------------------------------------------------------------------

def test_tree_block_topology():
    # 2x2 tree in block coordinates (+ a self-parent pad slot)
    pb = jnp.asarray([0, 0, 0, 1, 1, 2, 2, 7], jnp.int32)
    anc, depth = VD.tree_block_topology(pb, 8)
    anc, depth = np.asarray(anc), np.asarray(depth)
    assert list(depth) == [0, 1, 1, 2, 2, 2, 2, 0]
    assert anc[3, 0] and anc[3, 1] and anc[3, 3]
    assert not anc[3, 2] and not anc[3, 4]
    assert anc[6, 2] and anc[6, 0] and not anc[6, 1]
    # pad slot: itself only (plus the prefix, handled by the mask)
    assert anc[7, 7] and not anc[7, :7].any()
    # chain block parents give the causal (lower-triangular) mask
    anc_c, depth_c = VD.tree_block_topology(
        jnp.asarray([0, 0, 1, 2, 3, 4, 5, 6], jnp.int32), 8
    )
    assert np.array_equal(np.asarray(anc_c), np.tril(np.ones((8, 8), bool)))
    assert list(np.asarray(depth_c)) == list(range(8))


def test_tree_attention_chain_equals_causal_verify():
    """`target_verify_tree` with a chain topology is BIT-IDENTICAL to
    `target_verify` — tree attention generalizes the causal mask."""
    cfg = M.TARGETS["dense-s"]
    params = M.init_target(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    b, t, sp = 2, 8, 12
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, 32)), jnp.int32)
    _, kv, _ = M.target_prefill(params, prompt, jnp.int32(sp), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32)
    pos = jnp.asarray([sp, sp - 2], jnp.int32)
    lg_c, kv_c, ft_c = M.target_verify(params, kv, tokens, pos, cfg)
    anc, depth = VD.tree_block_topology(
        jnp.asarray([0, 0, 1, 2, 3, 4, 5, 6], jnp.int32), t
    )
    lg_t, kv_t, ft_t = M.target_verify_tree(params, kv, tokens, pos, anc, depth, cfg)
    assert float(jnp.max(jnp.abs(lg_c - lg_t))) == 0.0
    assert float(jnp.max(jnp.abs(kv_c - kv_t))) == 0.0
    assert float(jnp.max(jnp.abs(ft_c - ft_t))) == 0.0


def test_tree_attention_siblings_are_independent():
    """Sibling candidates must NOT see each other: swapping sibling 2's
    token cannot change sibling 1's logits row."""
    cfg = M.TARGETS["dense-s"]
    params = M.init_target(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(4)
    b, t, sp = 1, 8, 12
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, 32)), jnp.int32)
    _, kv, _ = M.target_prefill(params, prompt, jnp.int32(sp), cfg)
    anc, depth = VD.tree_block_topology(
        jnp.asarray([0, 0, 0, 1, 1, 2, 2, 7], jnp.int32), t
    )
    toks = rng.integers(0, cfg.vocab, (b, t)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, 2] = (toks2[0, 2] + 1) % cfg.vocab  # perturb sibling node 1
    pos = jnp.asarray([sp], jnp.int32)
    lg1, _, _ = M.target_verify_tree(params, kv, jnp.asarray(toks), pos, anc, depth, cfg)
    lg2, _, _ = M.target_verify_tree(params, kv, jnp.asarray(toks2), pos, anc, depth, cfg)
    # slot 1 (node 0) and its subtree slots 3,4 are unchanged…
    for slot in (0, 1, 3, 4):
        np.testing.assert_array_equal(np.asarray(lg1)[0, slot], np.asarray(lg2)[0, slot])
    # …while the perturbed slot's own logits move
    assert float(jnp.max(jnp.abs(lg1[0, 2] - lg2[0, 2]))) > 0


def test_kth_argmax_matches_stable_argsort():
    rng = np.random.default_rng(7)
    for _ in range(10):
        p = rng.random((3, 16)).astype(np.float32)
        for r in range(5):
            got = np.asarray(VD.kth_argmax(jnp.asarray(p), jnp.int32(r), 5))
            want = np.argsort(-p, axis=-1, kind="stable")[:, r]
            np.testing.assert_array_equal(got, want)


def test_tree_draft_sample_levels_and_ranks():
    rng = np.random.default_rng(8)
    kh, b, v = 3, 2, 32
    head_logits = jnp.asarray(rng.normal(0, 2, (kh, b, v)), jnp.float32)
    level = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
    rank = jnp.asarray([0, 1, 0, 1, 0, 1], jnp.int32)
    u = jnp.asarray(rng.random((b, 6)), jnp.float32)
    # greedy: node tokens are the level head's rank-th largest
    toks, qs = VD.tree_draft_sample(
        head_logits, u, level, rank, jnp.float32(1.0), jnp.int32(0), 6, 6
    )
    qh = np.asarray(jax.nn.softmax(head_logits))
    assert len(qs) == 6
    for i in range(6):
        lvl, rk = int(level[i]), int(rank[i])
        np.testing.assert_allclose(np.asarray(qs[i]), qh[lvl], rtol=1e-6)
        want = np.argsort(-qh[lvl], axis=-1, kind="stable")[:, rk]
        np.testing.assert_array_equal(np.asarray(toks)[:, i], want)
    # stochastic: per-node inverse-CDF draws through the node's uniform
    toks_s, _ = VD.tree_draft_sample(
        head_logits, u, level, rank, jnp.float32(1.0), jnp.int32(1), 6, 6
    )
    for i in range(6):
        for row in range(b):
            want = _host_threshold_select(
                qh[int(level[i])][row].astype(np.float64), float(u[row, i])
            )
            assert int(toks_s[row, i]) == want
