"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes/scales; explicit small block sizes exercise true
multi-(row, vocab)-block accumulation paths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, strategies as st

from compile.kernels import attention, lk_loss, ref, verify


def rand(key, shape, scale):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# softmax stats
# ---------------------------------------------------------------------------

@given(
    n=st.sampled_from([1, 3, 8]),
    v=st.sampled_from([32, 96, 512]),
    scale=st.sampled_from([0.1, 3.0, 30.0]),
    rb=st.sampled_from([2, 256]),
    vb=st.sampled_from([16, 512]),
)
def test_softmax_stats_matches_ref(n, v, scale, rb, vb):
    z = rand(0, (n, v), scale)
    m, lse = lk_loss.fused_softmax_stats(z, row_block=rb, vocab_block=vb)
    m_ref, lse_ref = ref.softmax_stats(z)
    np.testing.assert_allclose(m, m_ref, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(lse, lse_ref, rtol=1e-5, atol=1e-5)


def test_softmax_stats_extreme_logits():
    z = jnp.array([[-1e4, 0.0, 1e4, 1e4], [0.0, 0.0, 0.0, 0.0]], jnp.float32)
    _, lse = lk_loss.fused_softmax_stats(z, vocab_block=2)
    _, lse_ref = ref.softmax_stats(z)
    np.testing.assert_allclose(lse, lse_ref, rtol=1e-6)


# ---------------------------------------------------------------------------
# fused LK terms
# ---------------------------------------------------------------------------

@given(
    n=st.sampled_from([1, 4, 16]),
    v=st.sampled_from([64, 128, 512]),
    scale=st.sampled_from([0.5, 2.0, 8.0]),
)
def test_lk_terms_match_ref(n, v, scale):
    zp = rand(1, (n, v), scale)
    zq = rand(2, (n, v), scale)
    fused = lk_loss.fused_lk_terms(zp, zq)
    oracle = ref.lk_terms(zp, zq)
    for k in ("alpha", "tv", "kl"):
        np.testing.assert_allclose(fused[k], oracle[k], rtol=3e-5, atol=3e-6)


def test_lk_identities():
    """alpha = 1 - TV; KL >= 0; alpha in (0, 1]; alpha=1 iff p=q."""
    zp = rand(3, (32, 256), 3.0)
    t = lk_loss.fused_lk_terms(zp, zp)
    np.testing.assert_allclose(t["alpha"], 1.0, atol=1e-5)
    np.testing.assert_allclose(t["kl"], 0.0, atol=1e-5)
    zq = rand(4, (32, 256), 3.0)
    t = lk_loss.fused_lk_terms(zp, zq)
    np.testing.assert_allclose(t["alpha"], 1.0 - t["tv"], rtol=1e-5, atol=1e-6)
    assert (t["kl"] >= -1e-6).all()
    assert ((t["alpha"] > 0) & (t["alpha"] <= 1 + 1e-6)).all()


@given(
    v=st.sampled_from([128, 512]),
    vd=st.sampled_from([32, 96]),
    scale=st.sampled_from([1.0, 4.0]),
)
def test_lk_terms_truncated_match_ref(v, vd, scale):
    n = 8
    zp = rand(5, (n, v), scale)
    zq = rand(6, (n, vd), scale)
    vm = jnp.sort(
        jax.random.permutation(jax.random.PRNGKey(7), v)[:vd].astype(jnp.int32)
    )
    fused = lk_loss.fused_lk_terms_truncated(zp, zq, vm)
    oracle = ref.lk_terms_truncated(zp, zq, vm)
    for k in ("alpha", "tv", "kl", "p_in"):
        np.testing.assert_allclose(fused[k], oracle[k], rtol=3e-5, atol=3e-6)


def test_truncation_bounds():
    """alpha <= p_in (can't accept mass outside the draft vocab) and
    TV >= (1 - p_in)/2 wait: TV >= (1-p_in)/2... exact: TV = (tv_in + 1-p_in)/2
    >= (1-p_in)/2."""
    zp = rand(8, (16, 512), 3.0)
    zq = rand(9, (16, 128), 3.0)
    vm = jnp.arange(128, dtype=jnp.int32)
    t = lk_loss.fused_lk_terms_truncated(zp, zq, vm)
    assert (t["alpha"] <= t["p_in"] + 1e-6).all()
    assert (t["tv"] >= (1.0 - t["p_in"]) / 2.0 - 1e-6).all()


# ---------------------------------------------------------------------------
# attention kernel
# ---------------------------------------------------------------------------

@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 4]),
    sq=st.sampled_from([8, 64]),
    sk=st.sampled_from([64, 128]),
    off=st.sampled_from([0, 5, 50]),
)
def test_attention_matches_ref(b, h, sq, sk, off):
    if off + sq > sk:
        off = sk - sq
    d = 16
    q = rand(10, (b, h, sq, d), 1.0)
    k = rand(11, (b, h, sk, d), 1.0)
    v = rand(12, (b, h, sk, d), 1.0)
    kv_len = off + sq
    got = attention.flash_attention(q, k, v, off, kv_len, q_block=8, kv_block=16)
    want = ref.causal_attention(q, k, v, off, kv_len)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_attention_ignores_masked_garbage():
    """Entries beyond kv_len must not affect the output."""
    b, h, s, d = 1, 2, 32, 8
    q = rand(13, (b, h, 4, d), 1.0)
    k = rand(14, (b, h, s, d), 1.0)
    v = rand(15, (b, h, s, d), 1.0)
    out1 = attention.flash_attention(q, k, v, 10, 14, q_block=4, kv_block=8)
    # poison the region beyond kv_len
    k2 = k.at[:, :, 14:, :].set(1e3)
    v2 = v.at[:, :, 14:, :].set(-1e3)
    out2 = attention.flash_attention(q, k2, v2, 10, 14, q_block=4, kv_block=8)
    np.testing.assert_allclose(out1, out2, rtol=1e-6)


# ---------------------------------------------------------------------------
# verify kernel
# ---------------------------------------------------------------------------

@given(
    k=st.sampled_from([1, 4, 7]),
    v=st.sampled_from([64, 512]),
    sharp=st.sampled_from([1.0, 5.0]),
)
def test_verify_matches_ref(k, v, sharp):
    p = jax.nn.softmax(rand(16, (k, v), sharp))
    q = jax.nn.softmax(rand(17, (k, v), sharp))
    drafted = jax.random.randint(jax.random.PRNGKey(18), (k,), 0, v)
    bg, rg = verify.verify_probs(p, q, drafted, vocab_block=32)
    bw, rw = ref.verify_probs(p, q, drafted)
    np.testing.assert_allclose(bg, bw, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(rg, rw, rtol=1e-5, atol=1e-6)


def test_verify_residual_is_distribution():
    p = jax.nn.softmax(rand(19, (5, 128), 3.0))
    q = jax.nn.softmax(rand(20, (5, 128), 3.0))
    drafted = jnp.zeros((5,), jnp.int32)
    _, res = verify.verify_probs(p, q, drafted)
    np.testing.assert_allclose(res.sum(-1), 1.0, rtol=1e-5)
    assert (res >= 0).all()


def test_verify_identical_dists_accept_all():
    p = jax.nn.softmax(rand(21, (3, 64), 2.0))
    drafted = jnp.array([1, 5, 9], jnp.int32)
    beta, res = verify.verify_probs(p, p, drafted)
    np.testing.assert_allclose(beta, 1.0, rtol=1e-6)
    # residual falls back to p when p == q
    np.testing.assert_allclose(res, p, rtol=1e-5)
