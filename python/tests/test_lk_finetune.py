"""Trainer-side tests for the online adaptation loop (DESIGN.md §12):
LK loss terms vs hand-computed fixtures, the sim acceptance fit the Rust
`sim_finetune` mirrors, LKT checkpoint round-trip + corruption, swap
atomicity under a killed writer, and the stdout JSONL subprocess
contract `AdaptDriver` speaks.

Deliberately stdlib-only (no jax, no hypothesis): this suite must run on
the minimal CI image alongside the Rust swap-chaos tests.
"""

import json
import math
import os
import signal
import subprocess
import sys
import time

import pytest

from train import lk_finetune as lk

PY_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = os.path.join(PY_ROOT, "train", "lk_finetune.py")


def rec(slot, accept, p=None, q=None, **extra):
    r = {
        "session": 1,
        "round": extra.get("round", 0),
        "pos": 5,
        "slot": slot,
        "ctx": [-1, -1, 1001, 1002],
        "draft": 1003,
        "accept": accept,
    }
    if p is not None:
        r["p"] = p
    if q is not None:
        r["q"] = q
    return r


# ---------------------------------------------------------------------------
# LK terms on the two-atom collapse — hand-computed fixtures
# ---------------------------------------------------------------------------


def test_lk_terms_hand_computed():
    t = lk.lk_terms_2atom(0.8, 0.5)
    assert t["alpha"] == pytest.approx(0.7)
    assert t["tv"] == pytest.approx(0.3)
    assert t["kl"] == pytest.approx(0.8 * math.log(0.8 / 0.5) + 0.2 * math.log(0.2 / 0.5))
    assert t["nll"] == pytest.approx(-math.log(0.7))


def test_lk_terms_matched_distributions_are_free():
    t = lk.lk_terms_2atom(0.3, 0.3)
    assert t["alpha"] == pytest.approx(1.0)
    assert t["tv"] == 0.0
    assert t["kl"] == pytest.approx(0.0)
    assert t["nll"] == pytest.approx(0.0)


def test_lk_terms_disjoint_support_is_clamped_finite():
    t = lk.lk_terms_2atom(1.0, 0.0)
    assert t["alpha"] == 0.0
    assert t["tv"] == 1.0
    assert math.isfinite(t["kl"]) and t["kl"] > 20.0
    assert math.isfinite(t["nll"]) and t["nll"] > 20.0


# ---------------------------------------------------------------------------
# sim fit — the exact math the Rust BuiltinSim trainer runs in-process
# ---------------------------------------------------------------------------


def test_sim_fit_hand_computed_profile():
    records = (
        [rec(0, True)] * 3
        + [rec(0, False)]
        + [rec(1, True), rec(1, False)]
        + [rec(3, True)]
    )
    profile, a0, a1 = lk.sim_fit(records, k=4, gain=0.5)
    # slot0 alpha .75 -> .875; slot1 .5 -> .75; slot2 unexercised
    # inherits the FITTED .75 then gains again -> .875; slot3 1.0 -> 1.0.
    assert profile == pytest.approx([0.875, 0.75, 0.875, 1.0])
    assert a0 == pytest.approx(5 / 7)
    assert a1 == pytest.approx(5 / 7 + 0.5 * 2 / 7)


def test_sim_fit_empty_slots_default_half():
    profile, a0, a1 = lk.sim_fit([], k=2, gain=0.5)
    assert a0 == 0.0 and a1 == 0.5
    # slot0 defaults 0.5 -> 0.75; slot1 inherits 0.75 -> 0.875.
    assert profile == pytest.approx([0.75, 0.875])


# ---------------------------------------------------------------------------
# LK fit — descent moves the draft toward the target
# ---------------------------------------------------------------------------


def lk_records():
    out = []
    for i in range(24):
        out.append(rec(0, i % 3 != 0, p=0.9, q=0.4, round=i))
        out.append(rec(1, i % 2 == 0, p=0.7, q=0.2, round=i))
    return out


def test_lk_fit_improves_fitted_acceptance():
    records = lk_records()
    profile, a0, a1, theta = lk.lk_fit(records, k=2, gain=0.5)
    # Pre-fit two-atom acceptances: 1-|p-q| = 0.5 per slot.
    assert all(0.0 <= a <= 1.0 for a in profile)
    assert all(a > 0.5 for a in profile), profile
    assert all(t > 0.0 for t in theta), theta
    assert a1 > a0


def test_lk_fit_is_deterministic():
    a = lk.lk_fit(lk_records(), k=2, gain=0.5)
    b = lk.lk_fit(lk_records(), k=2, gain=0.5)
    assert a == b


def test_lk_fit_without_probs_falls_back_to_sim():
    records = [rec(0, True)] * 3 + [rec(0, False)]
    profile, a0, a1, theta = lk.lk_fit(records, k=1, gain=0.5)
    sim_profile, sim_a0, _ = lk.sim_fit(records, k=1, gain=0.5)
    assert profile == pytest.approx(sim_profile)
    assert a0 == pytest.approx(sim_a0)
    assert theta == [0.0]


# ---------------------------------------------------------------------------
# LKT checkpoint: round-trip, validation, swap atomicity
# ---------------------------------------------------------------------------


def test_lkt_roundtrip(tmp_path):
    path = str(tmp_path / "ck.lkt")
    meta = {"epoch": 3, "mode": "lk"}
    tensors = {
        "adapt/theta": ("f32", [2], [0.25, 0.5]),
        "adapt/profile": ("f32", [2], [0.625, 0.75]),
        "counts": ("i32", [3], [4, -2, 7]),
    }
    lk.write_lkt(path, meta, tensors)
    meta2, tensors2 = lk.read_lkt(path)
    assert meta2 == meta
    assert set(tensors2) == set(tensors)
    assert tensors2["counts"] == ("i32", [3], [4, -2, 7])
    got = tensors2["adapt/theta"]
    assert got[0] == "f32" and got[1] == [2]
    assert got[2] == pytest.approx([0.25, 0.5])


def test_lkt_rejects_corruption(tmp_path):
    path = str(tmp_path / "bad.lkt")
    with open(path, "wb") as f:
        f.write(b"NOPE")
    with pytest.raises(ValueError):
        lk.read_lkt(path)
    lk.write_lkt(path, {}, {"t": ("f32", [4], [0.0] * 4)})
    with open(path, "rb") as f:
        data = f.read()
    with open(path, "wb") as f:
        f.write(data[:-3])  # chop tensor data
    with pytest.raises(ValueError):
        lk.read_lkt(path)


def test_swap_atomicity_under_killed_writer(tmp_path):
    """Kill a writer mid-checkpoint repeatedly: the committed path must
    be absent or fully valid — never torn (tmp + os.replace)."""
    path = str(tmp_path / "live.lkt")
    child_src = (
        "import sys\n"
        f"sys.path.insert(0, {PY_ROOT!r})\n"
        "from train import lk_finetune as lk\n"
        "vals = [0.5] * 200_000\n"
        "i = 0\n"
        "while True:\n"
        f"    lk.write_lkt({path!r}, {{'i': i}}, {{'w': ('f32', [200_000], vals)}})\n"
        "    i += 1\n"
    )
    for trial in range(4):
        proc = subprocess.Popen([sys.executable, "-c", child_src])
        time.sleep(0.05 + 0.04 * trial)
        proc.send_signal(signal.SIGKILL)
        proc.wait()
        if os.path.exists(path):
            meta, tensors = lk.read_lkt(path)  # must parse cleanly
            assert tensors["w"][1] == [200_000]


# ---------------------------------------------------------------------------
# subprocess contract (what the Rust AdaptDriver speaks)
# ---------------------------------------------------------------------------


def run_trainer(tmp_path, records, mode=None, transcript_override=None):
    transcript = str(tmp_path / "transcript.jsonl")
    if transcript_override is None:
        with open(transcript, "w", encoding="utf-8") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    else:
        transcript = transcript_override
    config = str(tmp_path / "config.json")
    with open(config, "w", encoding="utf-8") as f:
        json.dump(
            {
                "transcript": transcript,
                "out_dir": str(tmp_path / "out"),
                "epoch": 2,
                "gain": 0.5,
            },
            f,
        )
    argv = [sys.executable, SCRIPT, "--config", config]
    if mode:
        argv += ["--mode", mode]
    proc = subprocess.run(argv, capture_output=True, text=True, timeout=120)
    events = [json.loads(line) for line in proc.stdout.splitlines() if line.strip()]
    assert all(set(e) == {"kind", "payload"} for e in events), proc.stdout
    return proc, events


@pytest.mark.parametrize("mode", [None, "lk"])
def test_trainer_contract_happy_path(tmp_path, mode):
    records = lk_records()
    proc, events = run_trainer(tmp_path, records, mode=mode)
    assert proc.returncode == 0, proc.stderr
    kinds = [e["kind"] for e in events]
    assert kinds[0] == "start" and kinds[-1] == "done"
    assert "progress" in kinds
    done = events[-1]["payload"]
    assert done["epoch"] == 2
    # The checkpoint the serving side validates-then-commits.
    with open(done["checkpoint"], "r", encoding="utf-8") as f:
        ckpt = json.load(f)
    assert ckpt["format"] == "lkspec-sim-draft"
    assert ckpt["epoch"] == 2
    assert ckpt["profile"] and all(0.0 <= a <= 1.0 for a in ckpt["profile"])
    # Manifest re-emitted next to it, LKT alongside.
    out_dir = os.path.dirname(done["checkpoint"])
    with open(os.path.join(out_dir, "manifest.json"), "r", encoding="utf-8") as f:
        manifest = json.load(f)
    assert manifest["epoch"] == 2 and manifest["checkpoint"] == done["checkpoint"]
    meta, tensors = lk.read_lkt(manifest["lkt"])
    assert meta["epoch"] == 2 and "adapt/profile" in tensors
    if mode == "lk":
        assert done["alpha_after"] > done["alpha_before"]


def test_trainer_error_is_a_protocol_event(tmp_path):
    proc, events = run_trainer(
        tmp_path, [], transcript_override=str(tmp_path / "missing.jsonl")
    )
    assert proc.returncode == 1
    assert [e["kind"] for e in events] == ["error"]
    assert events[0]["payload"]["message"]
