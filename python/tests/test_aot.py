"""AOT contract tests: manifest structure, HLO text properties, and the
flatten-order naming convention Rust depends on."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, drafts as D, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_configs():
    m = manifest()
    assert set(m["targets"]) == set(M.TARGETS)
    pairs = {d.name for d in aot.draft_pairs()}
    assert set(m["drafts"]) == pairs
    assert m["vocab"] == 512 and m["k_heads"] == 6


def test_manifest_entries_and_files_exist():
    m = manifest()
    for section in ("targets", "drafts"):
        for name, spec in m[section].items():
            assert spec["params"], name
            for ename, e in spec["entries"].items():
                path = os.path.join(ART, e["file"])
                assert os.path.exists(path), f"{name}:{ename} missing {e['file']}"
                assert e["inputs"] and e["outputs"], f"{name}:{ename}"


def test_hlo_text_parses_as_module():
    m = manifest()
    f = m["targets"]["dense-s"]["entries"]["decode_b1"]["file"]
    text = open(os.path.join(ART, f)).read()
    assert text.startswith("HloModule"), text[:40]
    # 64-bit-id safety: text interchange regenerates ids (see aot.py doc)
    assert "ENTRY" in text


def test_param_names_are_stable_paths():
    m = manifest()
    names = [p["name"] for p in m["targets"]["mtp-l"]["params"]]
    assert "embed" in names and "head" in names
    assert any(n.startswith("mtp/") for n in names), "mtp module params present"
    assert any(n.startswith("layers/0/") for n in names)
    # mirror of the python flatten order
    template = jax.eval_shape(
        lambda: M.init_target(jax.random.PRNGKey(0), M.TARGETS["mtp-l"])
    )
    spec, _ = aot.tree_spec(template)
    assert [s["name"] for s in spec] == names


def test_train_step_io_counts():
    """train_step returns params' + m' + v' + metrics, inputs include the
    runtime loss-selection scalars."""
    m = manifest()
    d = m["drafts"]["eagle3@dense-s"]
    n = len(d["params"])
    e = d["entries"]["train_step"]
    groups = [i["group"] for i in e["inputs"]]
    for g in ("tparams", "dparams", "opt_m", "opt_v", "loss_weights", "eta", "gamma", "lr", "vocab_map"):
        assert g in groups, g
    assert len(e["outputs"]) == 3 * n + 1
    # metrics vector layout [loss, mean_alpha, alpha*K, lambda*K]
    assert e["outputs"][-1]["shape"] == [2 + 2 * m["k_heads"]]


def test_serving_entry_shapes():
    m = manifest()
    t = m["targets"]["dense-s"]
    v1 = t["entries"]["verify_b1"]
    assert v1["outputs"][0]["shape"] == [1, m["verify_t"], 512]
    kv_shape = v1["outputs"][1]["shape"]
    assert kv_shape == [
        t["n_layers"], 2, 1, t["n_heads"], t["max_seq"], t["head_dim"]
    ]
    d = m["drafts"]["eagle3@dense-s"]
    s4 = d["entries"]["step_b4"]
    assert s4["outputs"][0]["shape"] == [4, d["draft_vocab"]]


def test_device_verify_entry_shapes():
    """The device-resident verify contract: uniforms in, O(B·K) verdicts
    out; q arrives as K separate [B, V] device tensors."""
    m = manifest()
    t = m["targets"]["dense-s"]
    kq = m["verify_t"] - 1
    vf = t["entries"]["verify_fused_b4"]
    groups = [i["group"] for i in vf["inputs"]]
    assert groups.count("q") == kq
    for g in ("u_acc", "u_samp", "temp", "mode", "k_active"):
        assert g in groups, g
    # outputs: n_acc, tokens_out, kv', feats, h_sel
    assert vf["outputs"][0] == {"shape": [4], "dtype": "int32"}
    assert vf["outputs"][1] == {"shape": [4, m["verify_t"]], "dtype": "int32"}
    assert vf["outputs"][4]["shape"] == [4, t["d_model"]]
    # device row copy: bucket-1 src spliced into the packed cache
    cp = t["entries"]["kv_copy_row_b4"]
    assert cp["inputs"][1]["shape"][2] == 1
    assert cp["outputs"][0]["shape"] == cp["inputs"][0]["shape"]


def test_device_draft_sample_entries():
    """Every draft arch carries its device-sampling entries: token ids to
    the host, full-vocab q on device."""
    m = manifest()
    v = m["vocab"]
    e3 = m["drafts"]["eagle3@dense-s"]["entries"]
    ss = e3["step_sample_b4"]
    assert ss["outputs"][0] == {"shape": [4], "dtype": "int32"}
    assert ss["outputs"][1]["shape"] == [4, v]  # full vocab, not draft_vocab
    assert any(i["group"] == "vocab_map" for i in ss["inputs"])
    ek = e3["extend_k_sample_b4"]
    feats = next(i for i in ek["inputs"] if i["group"] == "feats")
    assert feats["shape"] == [4, m["verify_t"],
                              m["targets"]["dense-s"]["feat_dim"]]
    assert "dkv_copy_row_b4" in e3
    md = m["drafts"]["medusa@dense-s"]["entries"]["propose_sample_b4"]
    assert md["outputs"][0] == {"shape": [4, m["k_heads"]], "dtype": "int32"}
    assert len(md["outputs"]) == 1 + m["k_heads"]
    ml = m["drafts"]["mlp@dense-s"]["entries"]["step_sample_b4"]
    assert ml["outputs"][0] == {"shape": [4], "dtype": "int32"}
    assert ml["outputs"][1]["shape"] == [4, v]
