"""L2: target transformer models (dense & mixture-of-experts).

Tiny but architecturally faithful analogs of the paper's six target
models (DESIGN.md §2): RMSNorm, RoPE, SwiGLU FFN, optional top-2 MoE
blocks, multi-layer feature taps for EAGLE-3 fusion, and an optional
native MTP module (DeepSeek-V3 analog). Everything is a pure function of
explicit parameter pytrees so the AOT layer can flatten them into a
stable manifest contract with the Rust runtime.

Graph entrypoints (lowered per config by `aot.py`):

  forward   — full-sequence training forward (logits + fusion feats)
  prefill   — prompt ingestion: fills the KV cache, returns logits/feats
  verify    — K+1-token speculative verification step against the cache
              (also lowered at T=1 as the vanilla `decode` baseline)

KV cache layout: [L, 2, B, H, Smax, Dh] — a dense per-sequence buffer.
Rollback after rejected drafts is free: the engine only tracks the valid
length; stale entries are either masked (j <= qpos, j < kv_len) or
overwritten by the next verify block at the same positions.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernels


@dataclasses.dataclass(frozen=True)
class TargetConfig:
    """Architecture of one target model (analog mapping in DESIGN.md §2)."""

    name: str
    vocab: int = 512
    d_model: int = 96
    n_layers: int = 4
    n_heads: int = 4
    ffn_mult: int = 4  # dense FFN intermediate = ffn_mult * d
    n_experts: int = 0  # 0 = dense; >0 = MoE with top-2 routing
    expert_mult: int = 2  # per-expert intermediate = expert_mult * d
    has_mtp: bool = False  # native multi-token-prediction module
    max_seq: int = 112  # KV buffer length (prompt + generation + drafts)
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def feat_dim(self) -> int:
        """EAGLE-3 fusion feature width: low/mid/high layer taps."""
        return 3 * self.d_model

    @property
    def taps(self) -> tuple[int, int, int]:
        low, mid, hi = 0, self.n_layers // 2, self.n_layers - 1
        return low, mid, hi


# ---------------------------------------------------------------------------
# parameter initialization
# ---------------------------------------------------------------------------

def _dense_ffn_init(key, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    sc_in = (2.0 / d) ** 0.5
    sc_out = (2.0 / f) ** 0.5
    return {
        "w1": jax.random.normal(k1, (d, f), dtype) * sc_in,
        "w3": jax.random.normal(k2, (d, f), dtype) * sc_in,
        "w2": jax.random.normal(k3, (f, d), dtype) * sc_out,
    }


def layer_init(key, cfg: TargetConfig, dtype=jnp.float32) -> dict[str, Any]:
    """One transformer block's parameters (shared by target & drafts)."""
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    sc = (2.0 / d) ** 0.5
    p = {
        "wq": jax.random.normal(keys[0], (d, d), dtype) * sc,
        "wk": jax.random.normal(keys[1], (d, d), dtype) * sc,
        "wv": jax.random.normal(keys[2], (d, d), dtype) * sc,
        "wo": jax.random.normal(keys[3], (d, d), dtype) * sc,
        "ln1": jnp.ones((d,), dtype),
        "ln2": jnp.ones((d,), dtype),
    }
    if cfg.n_experts > 0:
        fe = cfg.expert_mult * d
        ek = jax.random.split(keys[4], 4)
        sc_in = (2.0 / d) ** 0.5
        sc_out = (2.0 / fe) ** 0.5
        p["moe"] = {
            "gate": jax.random.normal(ek[0], (d, cfg.n_experts), dtype) * sc_in,
            "w1": jax.random.normal(ek[1], (cfg.n_experts, d, fe), dtype) * sc_in,
            "w3": jax.random.normal(ek[2], (cfg.n_experts, d, fe), dtype) * sc_in,
            "w2": jax.random.normal(ek[3], (cfg.n_experts, fe, d), dtype) * sc_out,
        }
    else:
        p["ffn"] = _dense_ffn_init(keys[5], d, cfg.ffn_mult * d, dtype)
    return p


def init_target(key, cfg: TargetConfig, dtype=jnp.float32) -> dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 4)
    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "layers": [layer_init(keys[1 + i], cfg, dtype) for i in range(cfg.n_layers)],
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "head": jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab), dtype)
        * (2.0 / cfg.d_model) ** 0.5,
    }
    if cfg.has_mtp:
        mk = jax.random.split(keys[-1], 3)
        params["mtp"] = {
            "proj": jax.random.normal(mk[0], (2 * cfg.d_model, cfg.d_model), dtype)
            * (2.0 / (2 * cfg.d_model)) ** 0.5,
            "norm_emb": jnp.ones((cfg.d_model,), dtype),
            "norm_h": jnp.ones((cfg.d_model,), dtype),
            "layer": layer_init(mk[1], cfg, dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [B, H, S, Dh]; positions: [B, S] absolute
    (per-row offsets — the serving engine batches sequences of different
    lengths, so each row carries its own position base)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=x.dtype) / half)  # [half]
    ang = positions.astype(x.dtype)[..., None] * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, None, :, :]  # broadcast over heads
    sin = jnp.sin(ang)[:, None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _jnp_attention(q, k, v, q_offset, kv_len, anc=None):
    """Reference-path attention (XLA-fused); see kernels.attention for the
    Pallas version. Profiling note (DESIGN.md §7): interpret-mode Pallas in
    the serving hot path costs while-loop dispatch per tile on CPU, so the
    lowered artifacts use this path; the Pallas kernel is validated against
    the same oracle and is the real-TPU implementation.

    q_offset / kv_len are [B] vectors (per-row positions). `anc` switches
    the in-block mask from causal to tree attention: an [Sq, Sq] bool
    ancestor mask (anc[i, j] iff block slot j is i or an ancestor of i)
    scattered at each row's block offset — queries still see the whole
    committed prefix (< q_offset), but within the block only their own
    root-to-node path. A chain's ancestor mask is lower-triangular, so
    tree attention with a chain topology IS the causal mask (tested)."""
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    sq, sk = q.shape[2], k.shape[2]
    b = q.shape[0]
    qpos = q_offset[:, None, None] + jnp.arange(sq)[None, :, None]  # [B,Sq,1]
    jpos = jnp.arange(sk)[None, None, :]  # [1,1,Sk]
    if anc is None:
        mask = (jpos <= qpos) & (jpos < kv_len[:, None, None])  # [B,Sq,Sk]
    else:
        prefix = jnp.broadcast_to(jpos < q_offset[:, None, None], (b, sq, sk))
        blk = jnp.zeros((b, sq, sk), jnp.bool_)
        for bi in range(b):  # B <= 4; unrolled per-row scatter
            blk = jax.lax.dynamic_update_slice(
                blk, anc[None], (bi, 0, q_offset[bi])
            )
        mask = prefix | blk
    scores = jnp.where(mask[:, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def attention_block(
    lp: dict[str, Any],
    x: jax.Array,
    cfg: TargetConfig,
    kv: tuple[jax.Array, jax.Array] | None,
    pos,
    use_pallas: bool = False,
    tree=None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Self-attention sublayer with optional external KV cache.

    Args:
      x: [B, S, d] (already normed)
      kv: optional (k_cache, v_cache) [B, H, Smax, Dh] to read/extend
      pos: ABSOLUTE position of x[:, 0] per row — scalar or [B] vector
        (the engine batches sequences of different lengths)
      tree: optional (anc [S, S] bool, depth [S] i32) tree-attention
        topology: RoPE positions become pos + depth (a node's position is
        its root distance, not its block slot) and the in-block mask
        becomes the ancestor mask; KV is still WRITTEN at the linear
        block slots pos..pos+S-1 — the accepted path is spliced back to
        consecutive positions after verification.

    Returns (attn_out [B, S, d], new (k, v) caches). Without an external
    cache, k/v are just the block's own keys (training path).
    """
    h = cfg.n_heads
    b = x.shape[0]
    q = _split_heads(x @ lp["wq"], h)
    k = _split_heads(x @ lp["wk"], h)
    v = _split_heads(x @ lp["wv"], h)
    s = x.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))  # [B]
    if tree is None:
        positions = pos[:, None] + jnp.arange(s)[None, :]  # [B, S]
    else:
        positions = pos[:, None] + tree[1][None, :]  # [B, S] depth-based
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if kv is None:
        kc, vc = k, v
        kv_len = jnp.full((b,), s, jnp.int32)
        q_offset = jnp.zeros((b,), jnp.int32)
    else:
        kc, vc = kv
        for bi in range(b):  # B <= 4; unrolled per-row scatter
            kc = jax.lax.dynamic_update_slice(
                kc, k[bi : bi + 1], (bi, 0, pos[bi], 0)
            )
            vc = jax.lax.dynamic_update_slice(
                vc, v[bi : bi + 1], (bi, 0, pos[bi], 0)
            )
        kv_len = pos + s
        q_offset = pos
    if use_pallas:
        # The Pallas kernel takes scalar offsets (single-sequence shapes);
        # used on the training path where pos == 0 for every row.
        out = attn_kernels.flash_attention(q, kc, vc, 0, s)
    else:
        out = _jnp_attention(
            q, kc, vc, q_offset, kv_len, anc=None if tree is None else tree[0]
        )
    return _merge_heads(out) @ lp["wo"], (kc, vc)


def ffn_block(lp: dict[str, Any], x: jax.Array, cfg: TargetConfig) -> jax.Array:
    """SwiGLU FFN — dense, or top-2 MoE (dense dispatch over E tiny experts;
    at this scale computing all experts and masking is cheaper than gather
    scatter, and it lowers to clean HLO)."""
    if cfg.n_experts == 0:
        f = lp["ffn"]
        return (jax.nn.silu(x @ f["w1"]) * (x @ f["w3"])) @ f["w2"]
    moe = lp["moe"]
    gate_logits = x @ moe["gate"]  # [B, S, E]
    # Manual top-2 via max/mask/max: jax.lax.top_k lowers to an HLO TopK
    # attribute ("largest") that xla_extension 0.5.1's text parser rejects,
    # so the routing is expressed with plain reductions instead. A tiny
    # deterministic bias breaks ties so the one-hots are exact.
    e = cfg.n_experts
    g = gate_logits - jnp.arange(e, dtype=x.dtype) * 1e-6
    m1 = jnp.max(g, axis=-1, keepdims=True)
    oh1 = (g == m1).astype(x.dtype)  # [B, S, E]
    g2 = jnp.where(oh1 > 0, -jnp.inf, g)
    m2 = jnp.max(g2, axis=-1, keepdims=True)
    oh2 = (g2 == m2).astype(x.dtype)
    top_w = jax.nn.softmax(
        jnp.concatenate([m1, m2], axis=-1), axis=-1
    )  # renormalized top-2 [B, S, 2]
    # combined per-expert weight: [B, S, E]
    wts = top_w[..., 0:1] * oh1 + top_w[..., 1:2] * oh2

    def expert(i):
        return (jax.nn.silu(x @ moe["w1"][i]) * (x @ moe["w3"][i])) @ moe["w2"][i]

    all_out = jnp.stack([expert(i) for i in range(e)])  # [E, B, S, d]
    return jnp.einsum("bse,ebsd->bsd", wts, all_out)


def transformer_layer(
    lp, x, cfg, kv=None, pos=0, use_pallas=False, tree=None
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    a, new_kv = attention_block(
        lp, rmsnorm(x, lp["ln1"]), cfg, kv, pos, use_pallas, tree
    )
    x = x + a
    x = x + ffn_block(lp, rmsnorm(x, lp["ln2"]), cfg)
    return x, new_kv


# ---------------------------------------------------------------------------
# graph entrypoints
# ---------------------------------------------------------------------------

def target_forward(
    params, tokens: jax.Array, cfg: TargetConfig, use_pallas: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Training forward. tokens [B, S] -> (logits [B, S, V], feats [B, S, 3d])."""
    x = jnp.take(params["embed"], tokens, axis=0)
    taps = set(cfg.taps)
    feats = []
    for i, lp in enumerate(params["layers"]):
        x, _ = transformer_layer(lp, x, cfg, use_pallas=use_pallas)
        if i in taps:
            feats.append(x)
    while len(feats) < 3:  # duplicate taps in very shallow configs
        feats.append(feats[-1])
    h = rmsnorm(x, params["final_norm"])
    logits = h @ params["head"]
    return logits, jnp.concatenate(feats[:3], axis=-1)


def target_prefill(
    params, tokens: jax.Array, length, cfg: TargetConfig
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Prompt ingestion. tokens [B, Sp] (valid prefix ``length``).

    Returns (logits [B, Sp, V], kv [L, 2, B, H, Smax, Dh], feats [B, Sp, 3d]).
    Positions >= length produce garbage that is never read: the engine
    reads logits/feats at length-1 and the next verify overwrites cache
    entries from ``pos = length`` on.
    """
    b, sp = tokens.shape
    del length  # causality alone protects the valid prefix
    x = jnp.take(params["embed"], tokens, axis=0)
    kv_shape = (b, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    taps = set(cfg.taps)
    feats = []
    kvs = []
    for i, lp in enumerate(params["layers"]):
        kv0 = (jnp.zeros(kv_shape, x.dtype), jnp.zeros(kv_shape, x.dtype))
        x, kv_i = transformer_layer(lp, x, cfg, kv=kv0, pos=0)
        kvs.append(jnp.stack(kv_i))  # [2, B, H, Smax, Dh]
        if i in taps:
            feats.append(x)
    while len(feats) < 3:
        feats.append(feats[-1])
    h = rmsnorm(x, params["final_norm"])
    logits = h @ params["head"]
    return logits, jnp.stack(kvs), jnp.concatenate(feats[:3], axis=-1)


def target_verify(
    params, kv: jax.Array, tokens: jax.Array, pos, cfg: TargetConfig, tree=None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Speculative verification step (T = K+1 tokens, or T=1 for vanilla
    decode). tokens [B, T] are written to the cache at positions
    pos..pos+T-1 and attended causally against the valid prefix — or
    with tree attention when `tree` is given (see `target_verify_tree`).

    Returns (logits [B, T, V], kv', feats [B, T, 3d]).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    taps = set(cfg.taps)
    feats = []
    new_kvs = []
    for i, lp in enumerate(params["layers"]):
        kv_i = (kv[i, 0], kv[i, 1])
        x, kv_i = transformer_layer(lp, x, cfg, kv=kv_i, pos=pos, tree=tree)
        new_kvs.append(jnp.stack(kv_i))
        if i in taps:
            feats.append(x)
    while len(feats) < 3:
        feats.append(feats[-1])
    h = rmsnorm(x, params["final_norm"])
    logits = h @ params["head"]
    return logits, jnp.stack(new_kvs), jnp.concatenate(feats[:3], axis=-1)


def target_verify_tree(
    params, kv: jax.Array, tokens: jax.Array, pos, anc, depths, cfg: TargetConfig
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Tree-attention verification step for multi-candidate drafts.

    tokens [B, T] is the tree block (slot 0 = last accepted token, slot
    i+1 = candidate node i); `anc` [T, T] bool is the within-block
    ancestor mask and `depths` [T] i32 the per-slot root distances (see
    `verify_device.tree_block_topology`). Each slot attends to the
    committed prefix plus its own root path, and its RoPE position is
    pos + depth — so the logits at slot j give p(· | prefix, path-to-j),
    exactly the chain contract restricted to each root-to-leaf path. KV
    is written at the LINEAR slots pos..pos+T-1; the engine splices the
    accepted path back to consecutive positions after the verdict.

    A thin wrapper over `target_verify` (one shared body, tree-masked
    attention) so the chain/tree bit-identity can never drift.

    Returns (logits [B, T, V], kv', feats [B, T, 3d]).
    """
    return target_verify(params, kv, tokens, pos, cfg, tree=(anc, depths))


# ---------------------------------------------------------------------------
# native MTP module forward (DeepSeek-V3 analog)
# ---------------------------------------------------------------------------

def mtp_combine(params, tok_emb: jax.Array, h_prev: jax.Array) -> jax.Array:
    """MTP input fusion: concat(RMSNorm(emb), RMSNorm(h_prev)) @ proj."""
    mtp = params["mtp"]
    z = jnp.concatenate(
        [rmsnorm(tok_emb, mtp["norm_emb"]), rmsnorm(h_prev, mtp["norm_h"])],
        axis=-1,
    )
    return z @ mtp["proj"]


def mtp_forward_train(
    params, tokens: jax.Array, hidden: jax.Array, cfg: TargetConfig
) -> jax.Array:
    """MTP-1 logits during target pretraining (predicts x_{t+2} from
    hidden_t and embed(x_{t+1})): tokens [B, S] are the *next* tokens
    (pre-shifted by the caller), hidden [B, S, d] the final-layer stream.
    """
    emb = jnp.take(params["embed"], tokens, axis=0)
    x = mtp_combine(params, emb, hidden)
    x, _ = transformer_layer(params["mtp"]["layer"], x, cfg)
    h = rmsnorm(x, params["mtp"]["final_norm"])
    return h @ params["head"]


# ---------------------------------------------------------------------------
# the six paper-analog target configurations (DESIGN.md §2 table)
# ---------------------------------------------------------------------------

TARGETS: dict[str, TargetConfig] = {
    # Llama-3.1-8B-Instruct analog (dense, small)
    "dense-s": TargetConfig(name="dense-s", d_model=96, n_layers=4, n_heads=4),
    # Llama-3.3-70B-Instruct analog (dense, deeper/wider)
    "dense-m": TargetConfig(name="dense-m", d_model=128, n_layers=6, n_heads=8),
    # gpt-oss-20b analog (MoE, small)
    "moe-s": TargetConfig(name="moe-s", d_model=96, n_layers=4, n_heads=4, n_experts=4),
    # gpt-oss-120b analog (MoE, medium)
    "moe-m": TargetConfig(name="moe-m", d_model=128, n_layers=5, n_heads=8, n_experts=4),
    # Qwen3-235B-A22B analog (MoE, large)
    "moe-l": TargetConfig(name="moe-l", d_model=160, n_layers=6, n_heads=8, n_experts=4),
    # DeepSeek-V3 analog (MoE, large, native MTP module)
    "mtp-l": TargetConfig(
        name="mtp-l", d_model=160, n_layers=6, n_heads=8, n_experts=4, has_mtp=True
    ),
}
