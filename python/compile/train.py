"""L2: training steps (AdamW) for targets and drafts (paper §5.3).

Hyperparameters follow the paper: AdamW with (β1, β2) = (0.9, 0.95),
global-norm gradient clipping at 0.5, cosine LR schedule with warmup —
the schedule itself is computed by the Rust trainer, which passes the
per-step learning rate as a scalar input (keeping the artifact free of
training-length constants).

Both train steps are pure functions
    (params, m, v, step, batch, hyper-scalars) -> (params', m', v', metrics)
lowered once by `aot.py` and driven from `rust/src/train/`. Loss selection
for drafts is runtime data (loss_weights/eta/gamma) so one artifact serves
the paper's entire objective sweep.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import drafts as D
from . import losses
from . import model as M

ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8
CLIP_NORM = 0.5
MTP_PRETRAIN_WEIGHT = 0.3  # weight of the MTP-1 auxiliary loss in pretrain


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x * x) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def adamw_update(params, grads, m, v, step, lr):
    """One AdamW step. ``step`` is 1-based (i32 scalar)."""
    t = step.astype(jnp.float32)
    b1c = 1.0 - ADAM_B1**t
    b2c = 1.0 - ADAM_B2**t

    def upd(p, g, m_, v_):
        m_new = ADAM_B1 * m_ + (1.0 - ADAM_B1) * g
        v_new = ADAM_B2 * v_ + (1.0 - ADAM_B2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        return p - lr * mh / (jnp.sqrt(vh) + ADAM_EPS), m_new, v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    new = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [x[0] for x in new])
    new_m = jax.tree_util.tree_unflatten(tdef, [x[1] for x in new])
    new_v = jax.tree_util.tree_unflatten(tdef, [x[2] for x in new])
    return new_p, new_m, new_v


def zeros_like_tree(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


# ---------------------------------------------------------------------------
# target pretraining step
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token-level CE. logits [B, S, V], labels [B, S] int."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def target_train_step(
    params, m, v, step, tokens: jax.Array, lr, cfg: M.TargetConfig
):
    """Next-token LM pretraining; for has_mtp configs the native MTP module
    is co-trained on its 1-step-ahead objective only (DeepSeek-style: the
    released module is trained for the FIRST draft position — the decline
    at later positions is exactly what §5.2 fine-tuning addresses).

    tokens: [B, S+2] (the +2 supplies labels for LM and MTP-1).
    Returns (params', m', v', metrics[2] = [lm_loss, mtp_loss]).
    """

    def loss_fn(p):
        s = tokens.shape[1] - 2
        inp = tokens[:, :s]  # x_0..x_{s-1}
        logits, feats = M.target_forward(p, inp, cfg)
        lm = cross_entropy(logits, tokens[:, 1 : s + 1])
        mtp_loss = jnp.zeros(())
        if cfg.has_mtp:
            hidden = feats[..., -cfg.d_model :]
            mtp_logits = M.mtp_forward_train(p, tokens[:, 1 : s + 1], hidden, cfg)
            mtp_loss = cross_entropy(mtp_logits, tokens[:, 2 : s + 2])
        return lm + MTP_PRETRAIN_WEIGHT * mtp_loss, (lm, mtp_loss)

    grads, (lm, mtp_loss) = jax.grad(loss_fn, has_aux=True)(params)
    grads, _ = clip_by_global_norm(grads, CLIP_NORM)
    new_p, new_m, new_v = adamw_update(params, grads, m, v, step, lr)
    return new_p, new_m, new_v, jnp.stack([lm, mtp_loss])


# ---------------------------------------------------------------------------
# draft training step
# ---------------------------------------------------------------------------

def draft_train_step(
    tparams,
    dparams,
    m,
    v,
    step,
    tokens: jax.Array,
    loss_weights: jax.Array,
    eta: jax.Array,
    gamma: jax.Array,
    lr: jax.Array,
    vocab_map: jax.Array | None,
    dcfg: D.DraftConfig,
    span: int,
):
    """One LK-loss training step for any draft architecture.

    Args:
      tokens: [B, span+K+1] ground-truth window (the +K+1 supplies shifted
        inputs and the deepest head's comparison position)
      loss_weights: [4] = (w_kl, w_tv, w_lkα, w_lkλ) — runtime loss config
      vocab_map: [Vd] int32 (eagle3) or None

    Returns (dparams', m', v', metrics[2 + 2K]) with metrics layout
    [loss, mean_alpha, alpha_head_1..K, lambda_head_1..K].
    """
    k = dcfg.k_heads
    tcfg = dcfg.target
    s = span
    # Frozen target pass over the whole window (positions 0..span+K-1).
    t_inp = tokens[:, : s + k]
    tlogits, tfeats = M.target_forward(tparams, t_inp, tcfg)
    tlogits = jax.lax.stop_gradient(tlogits)
    tfeats = jax.lax.stop_gradient(tfeats)
    # Head n compares against target logits at positions n..n+span-1.
    z_p = jnp.stack(
        [jax.lax.dynamic_slice_in_dim(tlogits, n, s, axis=1) for n in range(1, k + 1)]
    )  # [K, B, S, V]
    masks = jnp.ones(z_p.shape[:3], tlogits.dtype)

    def loss_fn(dp):
        if dcfg.arch == "eagle3":
            feats = tfeats[:, :s]
            zq = D.draft_train_unroll(dp, tparams, feats, tokens, dcfg)
        elif dcfg.arch == "mtp":
            feats = tfeats[:, :s, -tcfg.d_model :]
            zq = D.draft_train_unroll(dp, tparams, feats, tokens, dcfg)
        elif dcfg.arch == "medusa":
            hidden = tfeats[:, :s, -tcfg.d_model :]
            zq = D.medusa_propose(dp, hidden, dcfg)
        elif dcfg.arch == "mlp":
            hidden = tfeats[:, :s, -tcfg.d_model :]
            zq = D.mlp_train_unroll(dp, tparams, hidden, tokens, dcfg)
        else:
            raise ValueError(dcfg.arch)
        total, metrics = losses.draft_loss(
            z_p, zq, masks, loss_weights, eta, gamma,
            vocab_map=vocab_map if dcfg.arch == "eagle3" else None,
        )
        return total, metrics

    (loss_val, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(dparams)
    grads, _ = clip_by_global_norm(grads, CLIP_NORM)
    new_p, new_m, new_v = adamw_update(dparams, grads, m, v, step, lr)
    metric_vec = jnp.concatenate(
        [
            jnp.stack([loss_val, metrics["mean_alpha"]]),
            metrics["alpha_heads"],
            metrics["lambda_heads"],
        ]
    )
    return new_p, new_m, new_v, metric_vec
