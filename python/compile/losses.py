"""L2: the LK loss family (paper §4) with closed-form custom-VJP.

Forward: the fused Pallas reduction kernels (`kernels.lk_loss`).
Backward: the paper's Appendix-A closed forms —

    ∇_{z_q} KL(p̃‖q)   = q − p̃                       (A.2)
    ∇_{z_q} TV(p, q)  = ½ q ⊙ (s − E_q[s])           (A.3)
    ∇_{z_q} α         = q ⊙ (a − E_q[a]),  a = 1{q<p}
    ∇_{z_q} (−log α)  = (1/α) ∇ TV                   (A.4)

The closed forms are exact (tests check them against jax.grad of the ref
implementation) and avoid differentiating through the interpret-mode
Pallas kernels, which do not support autodiff. The target side (z_p) is
always frozen — draft training never backprops into the target.

Loss selection is runtime data: `draft_loss` takes a 4-vector of weights
(w_kl, w_tv, w_lkα, w_lkλ) plus η and γ scalars, so a single lowered
train-step artifact serves every loss configuration in the paper's sweeps
("drop-in replacement", §1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import lk_loss as lk_kernels


# ---------------------------------------------------------------------------
# custom-VJP fused term computation
# ---------------------------------------------------------------------------
#
# lk_terms_op(z_p_sub, z_q, lse_p_full, lse_p_sub, lse_q)
#   -> (alpha, tv, kl, p_in)
#
# z_p_sub   : [N, Vd] target logits gathered onto the draft vocabulary
# z_q       : [N, Vd] draft logits
# lse_p_full: [N] logsumexp of the FULL target row (defines the original p)
# lse_p_sub : [N] logsumexp of z_p_sub (defines the masked target p̃)
# lse_q     : [N]
#
# Full-vocabulary case: pass lse_p_sub == lse_p_full (then p̃ == p, p_in→1).


@jax.custom_vjp
def lk_terms_op(z_p_sub, z_q, lse_p_full, lse_p_sub, lse_q):
    alpha, tv_in, kl, p_in = lk_kernels.fused_lk_reduce(
        z_p_sub, z_q, lse_p_full, lse_p_sub, lse_q
    )
    tv = 0.5 * (tv_in + (1.0 - p_in))
    return alpha, tv, kl, p_in


def _lk_terms_fwd(z_p_sub, z_q, lse_p_full, lse_p_sub, lse_q):
    out = lk_terms_op(z_p_sub, z_q, lse_p_full, lse_p_sub, lse_q)
    # Residuals: logits + normalizers (distributions are recomputed in the
    # backward — cheaper than storing three V-sized probability tensors).
    return out, (z_p_sub, z_q, lse_p_full, lse_p_sub, lse_q, out[0])


def _lk_terms_bwd(res, cts):
    z_p_sub, z_q, lse_p_full, lse_p_sub, lse_q, alpha = res
    d_alpha, d_tv, d_kl, d_pin = cts
    p = jnp.exp(z_p_sub - lse_p_full[:, None])  # original target on sub-vocab
    pt = jnp.exp(z_p_sub - lse_p_sub[:, None])  # masked target p̃
    q = jnp.exp(z_q - lse_q[:, None])

    # Appendix-A closed forms (w.r.t. draft logits only; target frozen).
    a = (q < p).astype(q.dtype)
    ea = jnp.sum(q * a, axis=-1, keepdims=True)
    g_alpha = q * (a - ea)

    s = jnp.sign(q - p)
    es = jnp.sum(q * s, axis=-1, keepdims=True)
    g_tv = 0.5 * q * (s - es)

    g_kl = q - pt

    dzq = (
        d_alpha[:, None] * g_alpha
        + d_tv[:, None] * g_tv
        + d_kl[:, None] * g_kl
    )
    # p_in and everything flowing through z_p / normalizers is frozen.
    zero = jnp.zeros_like(lse_q)
    return jnp.zeros_like(z_p_sub), dzq, zero, zero, zero


lk_terms_op.defvjp(_lk_terms_fwd, _lk_terms_bwd)


def lk_terms(
    z_p_full: jax.Array, z_q: jax.Array, vocab_map: jax.Array | None = None
) -> dict[str, jax.Array]:
    """Differentiable (w.r.t. z_q) LK terms for [..., V]-shaped logits.

    With ``vocab_map`` (int32 [Vd]) the draft logits live on a truncated
    vocabulary; α/TV are measured against the original target distribution
    and KL against the masked target (paper §4.4).
    """
    lead = z_q.shape[:-1]
    z_p2 = jax.lax.stop_gradient(z_p_full).reshape(-1, z_p_full.shape[-1])
    z_q2 = z_q.reshape(-1, z_q.shape[-1])
    _, lse_p_full = lk_kernels.fused_softmax_stats(z_p2)
    if vocab_map is None:
        z_p_sub = z_p2
        lse_p_sub = lse_p_full
    else:
        z_p_sub = jnp.take(z_p2, vocab_map, axis=-1)
        _, lse_p_sub = lk_kernels.fused_softmax_stats(z_p_sub)
    _, lse_q = lk_kernels.fused_softmax_stats(jax.lax.stop_gradient(z_q2))
    # lse_q is a function of z_q, but the closed-form backward already
    # accounts for the full softmax Jacobian, so it enters as a frozen
    # auxiliary value (stop_gradient above).
    alpha, tv, kl, p_in = lk_terms_op(z_p_sub, z_q2, lse_p_full, lse_p_sub, lse_q)
    return {
        "alpha": alpha.reshape(lead),
        "tv": tv.reshape(lead),
        "kl": kl.reshape(lead),
        "p_in": p_in.reshape(lead),
    }


# ---------------------------------------------------------------------------
# per-head loss assembly with the adaptive λ schedule
# ---------------------------------------------------------------------------

def adaptive_lambda(alpha_agg: jax.Array, eta: jax.Array) -> jax.Array:
    """λ = exp(−η · sg[α])  (paper eq. 5). α is aggregated over batch and
    sequence dims per head before entering the schedule."""
    return jnp.exp(-eta * jax.lax.stop_gradient(alpha_agg))


def head_loss(
    terms: dict[str, jax.Array],
    mask: jax.Array,
    loss_weights: jax.Array,
    eta: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Masked-mean loss for one draft head.

    Args:
      terms: alpha/tv/kl arrays of shape [B, S]
      mask: [B, S] validity (positions where the head's prediction target
        exists within the window)
      loss_weights: [4] = (w_kl, w_tv, w_lkα, w_lkλ)
      eta: scalar for the adaptive schedule

    Returns (loss, alpha_agg, lam).
    """
    msum = jnp.maximum(jnp.sum(mask), 1.0)

    def mmean(x):
        return jnp.sum(x * mask) / msum

    alpha_agg = mmean(terms["alpha"])
    lam = adaptive_lambda(alpha_agg, eta)
    kl_m = mmean(terms["kl"])
    tv_m = mmean(terms["tv"])
    # −log α is averaged over positions (log of per-position marginal
    # acceptance likelihoods — the MLE view of §4.3). Clamp for the rare
    # fully-disjoint row.
    nla_m = mmean(-jnp.log(jnp.maximum(terms["alpha"], 1e-12)))
    w = loss_weights
    loss = (
        w[0] * kl_m
        + w[1] * tv_m
        + w[2] * nla_m
        + w[3] * (lam * kl_m + (1.0 - lam) * tv_m)
    )
    return loss, alpha_agg, lam


def draft_loss(
    z_p_full: jax.Array,
    z_q_heads: jax.Array,
    head_masks: jax.Array,
    loss_weights: jax.Array,
    eta: jax.Array,
    gamma: jax.Array,
    vocab_map: jax.Array | None = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Aggregate LK loss across K draft heads (paper §5.3).

    Args:
      z_p_full: [K, B, S, V] target logits aligned per head (head n at
        position t is compared against the target's distribution for
        token t+n+1, i.e. target logits at t+n — the caller pre-shifts)
      z_q_heads: [K, B, S, Vd] draft logits per head
      head_masks: [K, B, S] position validity per head
      loss_weights: [4]; eta, gamma: scalars

    Head n receives weight γ^{n-1}, normalized — prioritizing early
    positions, which drive acceptance length.

    Returns (total_loss, metrics) with metrics:
      alpha_heads [K], lambda_heads [K], mean_alpha scalar.
    """
    k = z_q_heads.shape[0]
    losses, alphas, lams = [], [], []
    for n in range(k):
        terms = lk_terms(z_p_full[n], z_q_heads[n], vocab_map=vocab_map)
        loss_n, alpha_n, lam_n = head_loss(
            terms, head_masks[n], loss_weights, eta
        )
        losses.append(loss_n)
        alphas.append(alpha_n)
        lams.append(lam_n)
    hw = gamma ** jnp.arange(k, dtype=z_q_heads.dtype)
    hw = hw / jnp.sum(hw)
    total = sum(hw[n] * losses[n] for n in range(k))
    metrics = {
        "alpha_heads": jnp.stack(alphas),
        "lambda_heads": jnp.stack(lams),
        "mean_alpha": jnp.mean(jnp.stack(alphas)),
    }
    return total, metrics
