"""AOT lowering: every (config, entrypoint) -> artifacts/<name>.hlo.txt.

This is the ONLY place Python executes in the system's lifecycle: it
lowers the L2/L1 graphs once, writes HLO **text** plus `manifest.json`
(the Rust runtime's packing contract), and exits. Python never runs on
any training, serving or benchmarking path.

HLO *text* — not ``lowered.compile()`` artifacts nor serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Manifest contract (consumed by rust/src/runtime/manifest.rs):

  {
    "version": 1,
    "vocab": 512, "k_heads": 6, "span": 64, "prompt_len": 32, ...
    "targets": { "<name>": { <arch fields>,
        "params":  [ {"name","shape","dtype"}... ],   # checkpoint order
        "entries": { "<entry>": {"file", "inputs": [...], "outputs": [...] } } } },
    "drafts":  { "<arch>@<target>": { ... same structure ... } }
  }

Every entry's inputs/outputs are FLAT ordered lists; pytrees are
flattened with `jax.tree_util` default ordering (sorted dict keys) and
the manifest records the leaf path names so Rust checkpoints/params are
keyed by name, never by position guessing.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import drafts as D
from . import model as M
from . import train as T
from . import verify_device as VD

# ---------------------------------------------------------------------------
# global shape constants (mirrored in rust/src/config)
# ---------------------------------------------------------------------------

SPAN = 48          # draft-training span S
K_HEADS = 6        # trained draft positions (serving may chain further)
TRAIN_BATCH = 4
PROMPT_LEN = 32    # prefill bucket
VERIFY_T = 8       # K+1 tokens per verification round (K=7 eval max)
SERVE_BATCHES = (1, 4)
DRAFT_VOCAB = 320
PREFILL_CHUNK = 16  # chunked-prefill step (divides PROMPT_LEN)

# The sweep needs these (target, arch) pairs (DESIGN.md §5):
#   eagle3 on all non-mtp targets; medusa+mlp on dense-s; mtp on mtp-l.
def draft_pairs() -> list[D.DraftConfig]:
    pairs = []
    for tname, tcfg in M.TARGETS.items():
        if tname == "mtp-l":
            pairs.append(D.DraftConfig(arch="mtp", target=tcfg, k_heads=K_HEADS))
        else:
            pairs.append(
                D.DraftConfig(
                    arch="eagle3", target=tcfg, k_heads=K_HEADS,
                    draft_vocab=DRAFT_VOCAB,
                )
            )
    dense_s = M.TARGETS["dense-s"]
    pairs.append(D.DraftConfig(arch="medusa", target=dense_s, k_heads=K_HEADS))
    pairs.append(D.DraftConfig(arch="mlp", target=dense_s, k_heads=K_HEADS))
    return pairs


# ---------------------------------------------------------------------------
# flatten helpers
# ---------------------------------------------------------------------------

def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_spec(tree) -> tuple[list[dict], object]:
    """(ordered [{name, shape, dtype}], treedef) for a params template."""
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree)
    spec = [
        {
            "name": _leaf_name(path),
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        }
        for path, leaf in leaves_with_path
    ]
    return spec, treedef


def shape_structs(tree) -> list[jax.ShapeDtypeStruct]:
    return [
        jax.ShapeDtypeStruct(l.shape, l.dtype)
        for l in jax.tree_util.tree_leaves(tree)
    ]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class EntryWriter:
    """Lowers entry functions and records their manifest rows."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.stats = []

    def lower(self, name: str, fn, arg_groups: list[tuple[str, list]], outputs_fn=None):
        """Lower `fn(*flat_args)` at the shapes given by arg_groups.

        arg_groups: [(group_name, [ShapeDtypeStruct or concrete-template])].
        Returns the manifest entry dict.
        """
        flat_specs = []
        inputs_manifest = []
        for gname, structs in arg_groups:
            for i, s in enumerate(structs):
                flat_specs.append(jax.ShapeDtypeStruct(s.shape, s.dtype))
                inputs_manifest.append(
                    {
                        "group": gname,
                        "index": i,
                        "shape": list(s.shape),
                        "dtype": str(s.dtype),
                    }
                )
        t0 = time.time()
        lowered = jax.jit(fn, keep_unused=True).lower(*flat_specs)
        out_tree = jax.eval_shape(fn, *flat_specs)
        out_flat = jax.tree_util.tree_leaves(out_tree)
        outputs_manifest = [
            {"shape": list(o.shape), "dtype": str(o.dtype)} for o in out_flat
        ]
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        dt = time.time() - t0
        self.stats.append((name, len(text), dt))
        print(f"  lowered {name}: {len(text)//1024} KiB in {dt:.1f}s", flush=True)
        return {
            "file": fname,
            "inputs": inputs_manifest,
            "outputs": outputs_manifest,
        }


# ---------------------------------------------------------------------------
# scalar spec shorthands
# ---------------------------------------------------------------------------

def f32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


# ---------------------------------------------------------------------------
# target entries
# ---------------------------------------------------------------------------

def lower_target(w: EntryWriter, cfg: M.TargetConfig) -> dict:
    template = jax.eval_shape(
        lambda: M.init_target(jax.random.PRNGKey(0), cfg)
    )
    pspec, tdef = tree_spec(template)
    pstructs = shape_structs(template)
    n_params = len(pstructs)

    def unflatten(flat):
        return jax.tree_util.tree_unflatten(tdef, list(flat))

    entries = {}

    # --- init ---------------------------------------------------------
    def init_fn(seed):
        key = jax.random.wrap_key_data(seed, impl="threefry2x32")
        return tuple(jax.tree_util.tree_leaves(M.init_target(key, cfg)))

    entries["init"] = w.lower(
        f"tgt_{cfg.name}_init", init_fn, [("seed", [u32((2,))])]
    )

    # --- train step ----------------------------------------------------
    tokens_spec = i32((TRAIN_BATCH, SPAN + K_HEADS + 2))

    def train_fn(*flat):
        p = unflatten(flat[:n_params])
        m = unflatten(flat[n_params : 2 * n_params])
        v = unflatten(flat[2 * n_params : 3 * n_params])
        step, tokens, lr = flat[3 * n_params :]
        new_p, new_m, new_v, metrics = T.target_train_step(
            p, m, v, step, tokens, lr, cfg
        )
        return (
            tuple(jax.tree_util.tree_leaves(new_p))
            + tuple(jax.tree_util.tree_leaves(new_m))
            + tuple(jax.tree_util.tree_leaves(new_v))
            + (metrics,)
        )

    entries["train_step"] = w.lower(
        f"tgt_{cfg.name}_train_step",
        train_fn,
        [
            ("params", pstructs),
            ("opt_m", pstructs),
            ("opt_v", pstructs),
            ("step", [i32()]),
            ("tokens", [tokens_spec]),
            ("lr", [f32()]),
        ],
    )

    # --- prefill / verify / decode per serve batch ---------------------
    for b in SERVE_BATCHES:
        def prefill_fn(*flat, b=b):
            p = unflatten(flat[:n_params])
            tokens, length = flat[n_params:]
            return M.target_prefill(p, tokens, length, cfg)

        entries[f"prefill_b{b}"] = w.lower(
            f"tgt_{cfg.name}_prefill_b{b}",
            prefill_fn,
            [
                ("params", pstructs),
                ("tokens", [i32((b, PROMPT_LEN))]),
                ("length", [i32()]),
            ],
        )

        kv_spec = f32(
            (cfg.n_layers, 2, b, cfg.n_heads, cfg.max_seq, cfg.head_dim)
        )
        for ename, t in (("verify", VERIFY_T), ("decode", 1)):
            def step_fn(*flat, t=t):
                p = unflatten(flat[:n_params])
                kv, tokens, pos = flat[n_params:]
                return M.target_verify(p, kv, tokens, pos, cfg)

            entries[f"{ename}_b{b}"] = w.lower(
                f"tgt_{cfg.name}_{ename}_b{b}",
                step_fn,
                [
                    ("params", pstructs),
                    ("kv", [kv_spec]),
                    ("tokens", [i32((b, t))]),
                    ("pos", [i32((b,))]),  # per-row positions
                ],
            )

        # --- chunked prefill: one fixed-length chunk written at a
        # runtime position offset over a carried KV. This is exactly the
        # verify forward (same causal mask + RoPE arithmetic), so
        # composing chunks at pos = 0, C, 2C, ... over a zero-initialized
        # KV reproduces whole-prompt prefill for every computed position
        # — which is what lets a radix prefix hit skip whole chunks of
        # compute, not just KV capacity (DESIGN.md §11).
        def prefill_chunk_fn(*flat):
            p = unflatten(flat[:n_params])
            kv, tokens, pos = flat[n_params:]
            return M.target_verify(p, kv, tokens, pos, cfg)

        entries[f"prefill_chunk_b{b}"] = w.lower(
            f"tgt_{cfg.name}_prefill_chunk_b{b}",
            prefill_chunk_fn,
            [
                ("params", pstructs),
                ("kv", [kv_spec]),
                ("tokens", [i32((b, PREFILL_CHUNK))]),
                ("pos", [i32((b,))]),
            ],
        )

        # --- device-resident verify: target forward + fused rejection
        # sampling in one graph. Draft q's arrive as K separate [B, V]
        # device tensors (the draft *_sample entries produce them);
        # randomness is host-fed per-position uniforms. Returns only
        # O(B·K) verdict integers plus device-side kv/feats/hidden —
        # full-vocab logits never leave the graph (verify_device.py).
        kq = VERIFY_T - 1

        def verify_fused_fn(*flat):
            p = unflatten(flat[:n_params])
            kv, tokens, pos = flat[n_params : n_params + 3]
            qs = flat[n_params + 3 : n_params + 3 + kq]
            u_acc, u_samp, temp, mode, k_active = flat[n_params + 3 + kq :]
            logits, kv2, feats = M.target_verify(p, kv, tokens, pos, cfg)
            q = jnp.stack(qs, axis=1)  # [B, K, V]
            n_acc, toks = VD.fused_verify(
                logits, q, tokens[:, 1:], u_acc, u_samp, temp, mode, k_active
            )
            h_sel = VD.pick_hidden(feats, n_acc, cfg.d_model)
            return n_acc, toks, kv2, feats, h_sel

        entries[f"verify_fused_b{b}"] = w.lower(
            f"tgt_{cfg.name}_verify_fused_b{b}",
            verify_fused_fn,
            [
                ("params", pstructs),
                ("kv", [kv_spec]),
                ("tokens", [i32((b, VERIFY_T))]),
                ("pos", [i32((b,))]),
                ("q", [f32((b, cfg.vocab))] * kq),
                ("u_acc", [f32((b, kq))]),
                ("u_samp", [f32((b,))]),
                ("temp", [f32()]),
                ("mode", [i32()]),
                ("k_active", [i32()]),
            ],
        )

        # --- multi-candidate (tree) verification: the verify block is a
        # candidate TREE (slot 0 = root/last_token, node i at slot i+1,
        # topology as a parent-index tensor — spec::sampling::TreeSpec).
        # The plain entry runs the tree-attention forward for the host
        # rejection path; the fused sibling additionally runs the exact
        # multi-draft rejection walk in-graph over per-node q tensors and
        # splices the accepted path's KV back to consecutive positions,
        # so a steady-state round returns O(B·N) ints.
        def path_gather(kv, sel, dst0, b=b):
            """Per-row KV gather of `sel` positions, scattered linearly
            from dst0 (gathers read the pre-update cache; batch rows
            never overlap)."""
            out = kv
            for bi in range(b):  # B <= 4; unrolled per-row
                g = jnp.take(kv[:, :, bi], sel[bi], axis=3)
                out = jax.lax.dynamic_update_slice(
                    out, g[:, :, None], (0, 0, bi, 0, dst0[bi], 0)
                )
            return out

        def verify_tree_fn(*flat, b=b):
            p = unflatten(flat[:n_params])
            kv, tokens, pos, parents_blk = flat[n_params:]
            anc, depths = VD.tree_block_topology(parents_blk, VERIFY_T)
            return M.target_verify_tree(p, kv, tokens, pos, anc, depths, cfg)

        entries[f"verify_tree_b{b}"] = w.lower(
            f"tgt_{cfg.name}_verify_tree_b{b}",
            verify_tree_fn,
            [
                ("params", pstructs),
                ("kv", [kv_spec]),
                ("tokens", [i32((b, VERIFY_T))]),
                ("pos", [i32((b,))]),
                ("parents_blk", [i32((VERIFY_T,))]),
            ],
        )

        def verify_tree_fused_fn(*flat, b=b):
            p = unflatten(flat[:n_params])
            kv, tokens, pos, parents = flat[n_params : n_params + 4]
            qs = flat[n_params + 4 : n_params + 4 + kq]
            u_acc, u_samp, temp, mode, n_active = flat[n_params + 4 + kq :]
            parents_blk = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32), parents + 1]
            )
            anc, depths = VD.tree_block_topology(parents_blk, VERIFY_T)
            logits, kv2, feats = M.target_verify_tree(
                p, kv, tokens, pos, anc, depths, cfg
            )
            q = jnp.stack(qs, axis=1)  # [B, N, V]
            n_path, path, toks, stop_blk = VD.tree_verify(
                logits, q, tokens[:, 1:], parents, u_acc, u_samp, temp,
                mode, n_active,
            )
            sel = pos[:, None] + 1 + jnp.clip(path, 0, kq - 1)
            kv3 = path_gather(kv2, sel, pos + 1)
            h_sel = VD.pick_hidden(feats, stop_blk, cfg.d_model)
            return n_path, path, toks, kv3, feats, h_sel

        entries[f"verify_tree_fused_b{b}"] = w.lower(
            f"tgt_{cfg.name}_verify_tree_fused_b{b}",
            verify_tree_fused_fn,
            [
                ("params", pstructs),
                ("kv", [kv_spec]),
                ("tokens", [i32((b, VERIFY_T))]),
                ("pos", [i32((b,))]),
                ("parents", [i32((kq,))]),
                ("q", [f32((b, cfg.vocab))] * kq),
                ("u_acc", [f32((b, kq))]),
                ("u_samp", [f32((b,))]),
                ("temp", [f32()]),
                ("mode", [i32()]),
                ("n_active", [i32()]),
            ],
        )

        # Host-path sibling of the in-graph splice: flatten an accepted
        # tree path to consecutive cache positions without pulling the
        # packed KV through the host.
        def kv_path_gather_fn(kv, sel, dst0, b=b):
            return (path_gather(kv, sel, dst0, b=b),)

        entries[f"kv_path_gather_b{b}"] = w.lower(
            f"tgt_{cfg.name}_kv_path_gather_b{b}",
            kv_path_gather_fn,
            [
                ("kv", [kv_spec]),
                ("sel", [i32((b, kq))]),
                ("dst0", [i32((b,))]),
            ],
        )

        # --- device-side one-row KV copy for scheduler joins: splice a
        # freshly prefilled bucket-1 cache row into a running group's
        # packed cache without the host round-trip.
        kv1_spec = f32(
            (cfg.n_layers, 2, 1, cfg.n_heads, cfg.max_seq, cfg.head_dim)
        )

        def kv_copy_row_fn(dst, src, row):
            return (jax.lax.dynamic_update_slice(dst, src, (0, 0, row, 0, 0, 0)),)

        entries[f"kv_copy_row_b{b}"] = w.lower(
            f"tgt_{cfg.name}_kv_copy_row_b{b}",
            kv_copy_row_fn,
            [("dst", [kv_spec]), ("src", [kv1_spec]), ("row", [i32()])],
        )

    # --- device-side cross-bucket KV row gather (scheduler migrations):
    # dst row i <- src row row_map[i] along the batch axis (axis 2 of
    # [L, 2, B, H, S, Dh]). row_map may REPEAT a source row (padding
    # clones), so one call re-packs a whole group for an up/downshift
    # with zero KV bytes through the host. Contract pinned by
    # rust server::kv::gather_rows and tests/test_kv_gather.py.
    for bsrc in SERVE_BATCHES:
        for bdst in SERVE_BATCHES:
            if bsrc == bdst:
                continue
            src_spec = f32(
                (cfg.n_layers, 2, bsrc, cfg.n_heads, cfg.max_seq, cfg.head_dim)
            )

            def kv_gather_rows_fn(src, row_map):
                return (VD.gather_rows(src, row_map, 2),)

            entries[f"kv_gather_rows_b{bsrc}x{bdst}"] = w.lower(
                f"tgt_{cfg.name}_kv_gather_rows_b{bsrc}x{bdst}",
                kv_gather_rows_fn,
                [("src", [src_spec]), ("row_map", [i32((bdst,))])],
            )

    return {
        "kind": "target",
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim,
        "n_experts": cfg.n_experts,
        "has_mtp": cfg.has_mtp,
        "max_seq": cfg.max_seq,
        "feat_dim": cfg.feat_dim,
        "params": pspec,
        "entries": entries,
    }


# ---------------------------------------------------------------------------
# draft entries
# ---------------------------------------------------------------------------

def lower_draft(w: EntryWriter, dcfg: D.DraftConfig) -> dict:
    tcfg = dcfg.target
    t_template = jax.eval_shape(lambda: M.init_target(jax.random.PRNGKey(0), tcfg))
    t_structs = shape_structs(t_template)
    _, t_def = tree_spec(t_template)
    n_t = len(t_structs)

    d_template = jax.eval_shape(lambda: D.init_draft(jax.random.PRNGKey(0), dcfg))
    d_spec, d_def = tree_spec(d_template)
    d_structs = shape_structs(d_template)
    n_d = len(d_structs)

    def unflat_t(flat):
        return jax.tree_util.tree_unflatten(t_def, list(flat))

    def unflat_d(flat):
        return jax.tree_util.tree_unflatten(d_def, list(flat))

    tag = dcfg.name.replace("@", "_")
    entries = {}

    # --- init -----------------------------------------------------------
    def init_fn(seed):
        key = jax.random.wrap_key_data(seed, impl="threefry2x32")
        return tuple(jax.tree_util.tree_leaves(D.init_draft(key, dcfg)))

    entries["init"] = w.lower(f"dr_{tag}_init", init_fn, [("seed", [u32((2,))])])

    # --- train step -------------------------------------------------------
    tokens_spec = i32((TRAIN_BATCH, SPAN + K_HEADS + 1))
    use_vmap = dcfg.arch == "eagle3"
    vm_group = [("vocab_map", [i32((dcfg.draft_vocab,))])] if use_vmap else []

    def train_fn(*flat):
        tp = unflat_t(flat[:n_t])
        dp = unflat_d(flat[n_t : n_t + n_d])
        m = unflat_d(flat[n_t + n_d : n_t + 2 * n_d])
        v = unflat_d(flat[n_t + 2 * n_d : n_t + 3 * n_d])
        rest = flat[n_t + 3 * n_d :]
        if use_vmap:
            step, tokens, loss_w, eta, gamma, lr, vocab_map = rest
        else:
            step, tokens, loss_w, eta, gamma, lr = rest
            vocab_map = None
        new_p, new_m, new_v, metrics = T.draft_train_step(
            tp, dp, m, v, step, tokens, loss_w, eta, gamma, lr, vocab_map,
            dcfg, SPAN,
        )
        return (
            tuple(jax.tree_util.tree_leaves(new_p))
            + tuple(jax.tree_util.tree_leaves(new_m))
            + tuple(jax.tree_util.tree_leaves(new_v))
            + (metrics,)
        )

    entries["train_step"] = w.lower(
        f"dr_{tag}_train_step",
        train_fn,
        [
            ("tparams", t_structs),
            ("dparams", d_structs),
            ("opt_m", d_structs),
            ("opt_v", d_structs),
            ("step", [i32()]),
            ("tokens", [tokens_spec]),
            ("loss_weights", [f32((4,))]),
            ("eta", [f32()]),
            ("gamma", [f32()]),
            ("lr", [f32()]),
        ]
        + vm_group,
    )

    # --- serving entries -------------------------------------------------
    d = tcfg.d_model
    for b in SERVE_BATCHES:
        if dcfg.is_recurrent:
            dkv_spec = f32((2, b, tcfg.n_heads, tcfg.max_seq, tcfg.head_dim))
            fdim = dcfg.fuse_dim
            for ename, t in (("extend_p", PROMPT_LEN), ("extend_k", VERIFY_T)):
                def ext_fn(*flat, t=t):
                    tp = unflat_t(flat[:n_t])
                    dp = unflat_d(flat[n_t : n_t + n_d])
                    dkv, feats, tokens_next, pos = flat[n_t + n_d :]
                    return D.draft_extend(dp, tp, dkv, feats, tokens_next, pos, dcfg)

                entries[f"{ename}_b{b}"] = w.lower(
                    f"dr_{tag}_{ename}_b{b}",
                    ext_fn,
                    [
                        ("tparams", t_structs),
                        ("dparams", d_structs),
                        ("dkv", [dkv_spec]),
                        ("feats", [f32((b, t, fdim))]),
                        ("tokens_next", [i32((b, t))]),
                        ("pos", [i32((b,))]),  # per-row positions
                    ],
                )

            def step_fn(*flat):
                tp = unflat_t(flat[:n_t])
                dp = unflat_d(flat[n_t : n_t + n_d])
                dkv, h_prev, token, pos = flat[n_t + n_d :]
                return D.draft_step(dp, tp, dkv, h_prev, token, pos, dcfg)

            entries[f"step_b{b}"] = w.lower(
                f"dr_{tag}_step_b{b}",
                step_fn,
                [
                    ("tparams", t_structs),
                    ("dparams", d_structs),
                    ("dkv", [dkv_spec]),
                    ("h_prev", [f32((b, d))]),
                    ("token", [i32((b,))]),
                    ("pos", [i32((b,))]),  # per-row positions
                ],
            )

            # --- device-verify variants: draft sampling happens in-graph
            # from host-fed uniforms; the full-vocab q flows on to the
            # target's verify_fused entry without touching the host.
            vm_in = vm_group  # eagle3: trailing vocab_map input

            def step_sample_fn(*flat):
                tp = unflat_t(flat[:n_t])
                dp = unflat_d(flat[n_t : n_t + n_d])
                rest = flat[n_t + n_d :]
                if use_vmap:
                    dkv, h_prev, token, pos, u, temp, mode, vocab_map = rest
                else:
                    dkv, h_prev, token, pos, u, temp, mode = rest
                    vocab_map = None
                qlog, h, dkv2 = D.draft_step(dp, tp, dkv, h_prev, token, pos, dcfg)
                tok, q_full = VD.draft_q_and_sample(
                    qlog, u, temp, mode, vocab_map, tcfg.vocab
                )
                return tok, q_full, h, dkv2

            entries[f"step_sample_b{b}"] = w.lower(
                f"dr_{tag}_step_sample_b{b}",
                step_sample_fn,
                [
                    ("tparams", t_structs),
                    ("dparams", d_structs),
                    ("dkv", [dkv_spec]),
                    ("h_prev", [f32((b, d))]),
                    ("token", [i32((b,))]),
                    ("pos", [i32((b,))]),
                    ("u", [f32((b,))]),
                    ("temp", [f32()]),
                    ("mode", [i32()]),
                ]
                + vm_in,
            )

            # Extend + in-graph pickup of the next round's first draft:
            # consumes the verify pass's FULL [B, T, 3d] features (device
            # tensor), slices the draft's fusion columns internally, and
            # gathers q/h at the per-row accepted-prefix index `sel`.
            for ename, t in (
                ("extend_p_sample", PROMPT_LEN),
                ("extend_k_sample", VERIFY_T),
            ):
                def ext_sample_fn(*flat, t=t):
                    tp = unflat_t(flat[:n_t])
                    dp = unflat_d(flat[n_t : n_t + n_d])
                    rest = flat[n_t + n_d :]
                    if use_vmap:
                        (dkv, feats_full, tokens_next, pos, sel, u, temp,
                         mode, vocab_map) = rest
                    else:
                        (dkv, feats_full, tokens_next, pos, sel, u, temp,
                         mode) = rest
                        vocab_map = None
                    feats = feats_full[..., tcfg.feat_dim - fdim :]
                    qlog, h, dkv2 = D.draft_extend(
                        dp, tp, dkv, feats, tokens_next, pos, dcfg
                    )
                    q_sel = jnp.take_along_axis(
                        qlog, sel[:, None, None], axis=1
                    )[:, 0]
                    h_sel = jnp.take_along_axis(
                        h, sel[:, None, None], axis=1
                    )[:, 0]
                    tok, q_full = VD.draft_q_and_sample(
                        q_sel, u, temp, mode, vocab_map, tcfg.vocab
                    )
                    return tok, q_full, h_sel, dkv2

                entries[f"{ename}_b{b}"] = w.lower(
                    f"dr_{tag}_{ename}_b{b}",
                    ext_sample_fn,
                    [
                        ("tparams", t_structs),
                        ("dparams", d_structs),
                        ("dkv", [dkv_spec]),
                        ("feats", [f32((b, t, tcfg.feat_dim))]),
                        ("tokens_next", [i32((b, t))]),
                        ("pos", [i32((b,))]),
                        ("sel", [i32((b,))]),
                        ("u", [f32((b,))]),
                        ("temp", [f32()]),
                        ("mode", [i32()]),
                    ]
                    + vm_in,
                )

            # Device-side one-row draft-KV copy (scheduler joins).
            dkv1_spec = f32((2, 1, tcfg.n_heads, tcfg.max_seq, tcfg.head_dim))

            def dkv_copy_row_fn(dst, src, row):
                return (
                    jax.lax.dynamic_update_slice(dst, src, (0, row, 0, 0, 0)),
                )

            entries[f"dkv_copy_row_b{b}"] = w.lower(
                f"dr_{tag}_dkv_copy_row_b{b}",
                dkv_copy_row_fn,
                [("dst", [dkv_spec]), ("src", [dkv1_spec]), ("row", [i32()])],
            )

            # --- multi-candidate (tree) drafting: the recurrent drafter
            # expands a candidate tree LEVEL-PARALLEL — one tree-attention
            # pass per level over all node slots, each node recurring on
            # its parent's hidden (drafts.draft_tree_step). Node i's KV
            # sits at draft slot pos + i; after the verdict the accepted
            # path is spliced to consecutive slots by dkv_path_gather —
            # the draft-side twin of the target's kv_path_gather.
            n_tree = VERIFY_T - 1

            def tree_step_fn(*flat):
                tp = unflat_t(flat[:n_t])
                dp = unflat_d(flat[n_t : n_t + n_d])
                dkv, h_prev, h_all, tokens, pos, parents = flat[n_t + n_d :]
                return D.draft_tree_step(
                    dp, tp, dkv, h_prev, h_all, tokens, pos, parents, dcfg
                )

            entries[f"tree_step_b{b}"] = w.lower(
                f"dr_{tag}_tree_step_b{b}",
                tree_step_fn,
                [
                    ("tparams", t_structs),
                    ("dparams", d_structs),
                    ("dkv", [dkv_spec]),
                    ("h_prev", [f32((b, d))]),
                    ("h_all", [f32((b, n_tree, d))]),
                    ("tokens", [i32((b, n_tree))]),
                    ("pos", [i32((b,))]),
                    ("parents", [i32((n_tree,))]),
                ],
            )

            # Draft-side path splice: flatten the accepted tree path's
            # draft-KV entries to consecutive cache positions (the next
            # round is topology-agnostic, like the target cache).
            def dkv_path_gather_fn(dkv, sel, dst0):
                return (D.dkv_path_gather(dkv, sel, dst0),)

            entries[f"dkv_path_gather_b{b}"] = w.lower(
                f"dr_{tag}_dkv_path_gather_b{b}",
                dkv_path_gather_fn,
                [
                    ("dkv", [dkv_spec]),
                    ("sel", [i32((b, n_tree))]),
                    ("dst0", [i32((b,))]),
                ],
            )

            # Device-path tree proposal: the WHOLE level-parallel
            # expansion in one graph. Node 0 is the extend-sampled first
            # draft (tok0/q0 ride in device-resident); its level-0
            # siblings sample from the same q0, deeper levels from their
            # parent's tree_step distribution — all through host-fed
            # per-node uniforms. The n_tree full-vocab q tensors flow
            # straight into verify_tree_fused.
            def rec_tree_sample_fn(*flat):
                tp = unflat_t(flat[:n_t])
                dp = unflat_d(flat[n_t : n_t + n_d])
                rest = flat[n_t + n_d :]
                if use_vmap:
                    (dkv, h_prev, tok0, q0, u, parents, ranks, pos, temp,
                     mode, vocab_map) = rest
                else:
                    (dkv, h_prev, tok0, q0, u, parents, ranks, pos, temp,
                     mode) = rest
                    vocab_map = None
                tokens, qs, dkv2 = D.draft_tree_propose(
                    dp, tp, dkv, h_prev, tok0, q0, u, parents, ranks, pos,
                    temp, mode, dcfg, vocab_map, tcfg.vocab, n_tree,
                )
                return (tokens,) + tuple(qs) + (dkv2,)

            entries[f"propose_tree_sample_b{b}"] = w.lower(
                f"dr_{tag}_propose_tree_sample_b{b}",
                rec_tree_sample_fn,
                [
                    ("tparams", t_structs),
                    ("dparams", d_structs),
                    ("dkv", [dkv_spec]),
                    ("h_prev", [f32((b, d))]),
                    ("tok0", [i32((b,))]),
                    ("q0", [f32((b, tcfg.vocab))]),
                    ("u", [f32((b, n_tree))]),
                    ("parents", [i32((n_tree,))]),
                    ("ranks", [i32((n_tree,))]),
                    ("pos", [i32((b,))]),
                    ("temp", [f32()]),
                    ("mode", [i32()]),
                ]
                + vm_in,
            )

            # Device-path tree advance: extend_k_sample with the verify
            # pass's TREE-layout features linearized in-graph along the
            # accepted path (blk maps chain row t -> block slot), so the
            # fused tree verify's feats output feeds back without a host
            # round-trip. Same output contract as extend_k_sample.
            def ext_tree_sample_fn(*flat):
                tp = unflat_t(flat[:n_t])
                dp = unflat_d(flat[n_t : n_t + n_d])
                rest = flat[n_t + n_d :]
                if use_vmap:
                    (dkv, feats_full, blk, tokens_next, pos, sel, u, temp,
                     mode, vocab_map) = rest
                else:
                    (dkv, feats_full, blk, tokens_next, pos, sel, u, temp,
                     mode) = rest
                    vocab_map = None
                feats_lin = jnp.take_along_axis(
                    feats_full, blk[:, :, None], axis=1
                )
                feats = feats_lin[..., tcfg.feat_dim - fdim :]
                qlog, h, dkv2 = D.draft_extend(
                    dp, tp, dkv, feats, tokens_next, pos, dcfg
                )
                q_sel = jnp.take_along_axis(
                    qlog, sel[:, None, None], axis=1
                )[:, 0]
                h_sel = jnp.take_along_axis(
                    h, sel[:, None, None], axis=1
                )[:, 0]
                tok, q_full = VD.draft_q_and_sample(
                    q_sel, u, temp, mode, vocab_map, tcfg.vocab
                )
                return tok, q_full, h_sel, dkv2

            entries[f"extend_tree_sample_b{b}"] = w.lower(
                f"dr_{tag}_extend_tree_sample_b{b}",
                ext_tree_sample_fn,
                [
                    ("tparams", t_structs),
                    ("dparams", d_structs),
                    ("dkv", [dkv_spec]),
                    ("feats", [f32((b, VERIFY_T, tcfg.feat_dim))]),
                    ("blk", [i32((b, VERIFY_T))]),
                    ("tokens_next", [i32((b, VERIFY_T))]),
                    ("pos", [i32((b,))]),
                    ("sel", [i32((b,))]),
                    ("u", [f32((b,))]),
                    ("temp", [f32()]),
                    ("mode", [i32()]),
                ]
                + vm_in,
            )
        elif dcfg.arch == "medusa":
            def prop_fn(*flat):
                dp = unflat_d(flat[:n_d])
                (hidden,) = flat[n_d:]
                return D.medusa_propose(dp, hidden, dcfg)

            entries[f"propose_b{b}"] = w.lower(
                f"dr_{tag}_propose_b{b}",
                prop_fn,
                [("dparams", d_structs), ("hidden", [f32((b, d))])],
            )

            def prop_sample_fn(*flat):
                dp = unflat_d(flat[:n_d])
                hidden, u, temp, mode = flat[n_d:]
                logits = D.medusa_propose(dp, hidden, dcfg)  # [K, B, V]
                toks, qs = [], []
                for i in range(dcfg.k_heads):
                    tok, qf = VD.draft_q_and_sample(
                        logits[i], u[:, i], temp, mode
                    )
                    toks.append(tok)
                    qs.append(qf)
                # tokens [B, K] to the host (O(B·K) ints); one [B, V] q
                # tensor per head straight into verify_fused.
                return (jnp.stack(toks, axis=1),) + tuple(qs)

            entries[f"propose_sample_b{b}"] = w.lower(
                f"dr_{tag}_propose_sample_b{b}",
                prop_sample_fn,
                [
                    ("dparams", d_structs),
                    ("hidden", [f32((b, d))]),
                    ("u", [f32((b, dcfg.k_heads))]),
                    ("temp", [f32()]),
                    ("mode", [i32()]),
                ],
            )

            # Tree drafting: every candidate node samples from its
            # LEVEL's head distribution (parallel heads are token-
            # independent, so one propose pass feeds the whole tree) —
            # i.i.d. through per-node uniforms in stochastic mode,
            # sibling-rank-th largest in the greedy modes. The N
            # full-vocab q tensors flow straight into verify_tree_fused.
            n_tree = VERIFY_T - 1

            def prop_tree_sample_fn(*flat):
                dp = unflat_d(flat[:n_d])
                hidden, u, level, rank, temp, mode = flat[n_d:]
                logits = D.medusa_propose(dp, hidden, dcfg)  # [K, B, V]
                toks, qs = VD.tree_draft_sample(
                    logits, u, level, rank, temp, mode, n_tree, n_tree
                )
                return (toks,) + tuple(qs)

            entries[f"propose_tree_sample_b{b}"] = w.lower(
                f"dr_{tag}_propose_tree_sample_b{b}",
                prop_tree_sample_fn,
                [
                    ("dparams", d_structs),
                    ("hidden", [f32((b, d))]),
                    ("u", [f32((b, n_tree))]),
                    ("level", [i32((n_tree,))]),
                    ("rank", [i32((n_tree,))]),
                    ("temp", [f32()]),
                    ("mode", [i32()]),
                ],
            )
        elif dcfg.arch == "mlp":
            def mstep_fn(*flat):
                tp = unflat_t(flat[:n_t])
                dp = unflat_d(flat[n_t : n_t + n_d])
                state, token, head_idx = flat[n_t + n_d :]
                return D.mlp_step(dp, tp, state, token, head_idx, dcfg)

            entries[f"step_b{b}"] = w.lower(
                f"dr_{tag}_step_b{b}",
                mstep_fn,
                [
                    ("tparams", t_structs),
                    ("dparams", d_structs),
                    ("state", [f32((b, d))]),
                    ("token", [i32((b,))]),
                    ("head_idx", [i32()]),
                ],
            )

            def mstep_sample_fn(*flat):
                tp = unflat_t(flat[:n_t])
                dp = unflat_d(flat[n_t : n_t + n_d])
                state, token, head_idx, u, temp, mode = flat[n_t + n_d :]
                logits, new_state = D.mlp_step(
                    dp, tp, state, token, head_idx, dcfg
                )
                tok, qf = VD.draft_q_and_sample(logits, u, temp, mode)
                return tok, qf, new_state

            entries[f"step_sample_b{b}"] = w.lower(
                f"dr_{tag}_step_sample_b{b}",
                mstep_sample_fn,
                [
                    ("tparams", t_structs),
                    ("dparams", d_structs),
                    ("state", [f32((b, d))]),
                    ("token", [i32((b,))]),
                    ("head_idx", [i32()]),
                    ("u", [f32((b,))]),
                    ("temp", [f32()]),
                    ("mode", [i32()]),
                ],
            )

    if dcfg.is_recurrent:
        # Draft-side twin of the target's cross-bucket row gather: the
        # recurrent drafter's KV migrates with the group (axis 1 of
        # [2, B, H, S, Dh]); head-less drafts carry no KV and need none.
        for bsrc in SERVE_BATCHES:
            for bdst in SERVE_BATCHES:
                if bsrc == bdst:
                    continue
                src_spec = f32(
                    (2, bsrc, tcfg.n_heads, tcfg.max_seq, tcfg.head_dim)
                )

                def dkv_gather_rows_fn(src, row_map):
                    return (VD.gather_rows(src, row_map, 1),)

                entries[f"dkv_gather_rows_b{bsrc}x{bdst}"] = w.lower(
                    f"dr_{tag}_dkv_gather_rows_b{bsrc}x{bdst}",
                    dkv_gather_rows_fn,
                    [("src", [src_spec]), ("row_map", [i32((bdst,))])],
                )

    return {
        "kind": "draft",
        "arch": dcfg.arch,
        "target": tcfg.name,
        "k_heads": dcfg.k_heads,
        "draft_vocab": dcfg.out_vocab,
        "is_recurrent": dcfg.is_recurrent,
        "fuse_dim": dcfg.fuse_dim if dcfg.is_recurrent else d,
        "own_head": dcfg.own_head,
        "params": d_spec,
        "entries": entries,
    }


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated subset of config names (targets or drafts) to lower",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    w = EntryWriter(args.out)
    manifest: dict = {
        "version": 1,
        "vocab": 512,
        "k_heads": K_HEADS,
        "span": SPAN,
        "train_batch": TRAIN_BATCH,
        "prompt_len": PROMPT_LEN,
        "verify_t": VERIFY_T,
        "prefill_chunk": PREFILL_CHUNK,
        "serve_batches": list(SERVE_BATCHES),
        "draft_vocab": DRAFT_VOCAB,
        "targets": {},
        "drafts": {},
    }

    t0 = time.time()
    for name, cfg in M.TARGETS.items():
        if only and name not in only:
            continue
        print(f"[target {name}]", flush=True)
        manifest["targets"][name] = lower_target(w, cfg)
    for dcfg in draft_pairs():
        if only and dcfg.name not in only and dcfg.target.name not in only:
            continue
        print(f"[draft {dcfg.name}]", flush=True)
        manifest["drafts"][dcfg.name] = lower_draft(w, dcfg)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    total = sum(s[1] for s in w.stats)
    print(
        f"wrote {len(w.stats)} artifacts ({total//1024} KiB) + manifest in "
        f"{time.time()-t0:.0f}s"
    )


if __name__ == "__main__":
    main()
