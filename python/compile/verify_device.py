"""Device-resident verification & sampling graphs (L1 -> AOT).

The serving engine's historical hot path pulled `[B, K+1, V]` full-vocab
target logits (plus every draft q distribution) to the host each round
and ran softmax + rejection sampling in Rust. The functions here move
that arithmetic in-graph so a decode round returns only O(B*K) integers:
`n_accepted`, the accepted/replacement token ids, and the bonus token.

Randomness stays HOST-OWNED: the engine draws per-position uniforms from
the existing request-keyed PCG64 streams and feeds them in as plain f32
inputs, so a sequence's sample path remains a pure function of
(seed, request id) — batch-composition independence and the scheduler's
continuous-vs-lockstep equivalence tests carry over unchanged.

Shared contract with `rust/src/spec/sampling.rs` (kept in lockstep; the
Rust side documents the same rules):

  * inverse-CDF selection returns the FIRST index with cumsum >= u,
    falling back to the LAST index with positive mass (fp slack);
  * acceptance at position j draws `u_acc[j] < beta_j` with
    beta = min(1, p(x)/q(x)) (stochastic), min(1, p(x)) (greedy-draft,
    the Appendix D bug) or the argmax-agreement indicator (greedy);
  * on the first rejection the replacement is sampled from the
    normalized residual max(p - q, 0) using the round's single sample
    uniform; on full acceptance the bonus token is sampled from p with
    that same uniform (exactly one of the two is consumed per round);
  * mode codes: 0 = greedy, 1 = stochastic, 2 = greedy-draft.

All ops are plain jnp so the graphs AOT-lower portably; the blocked
Pallas realization of the fused round lives in `kernels/fused_verify.py`
and is cross-checked against these functions by `tests/test_kernels.py`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

MODE_GREEDY = 0
MODE_STOCHASTIC = 1
MODE_GREEDY_DRAFT = 2


def categorical_from_uniform(probs: jax.Array, u: jax.Array) -> jax.Array:
    """Inverse-CDF sample: first index with cumsum(probs) >= u.

    Mirrors `spec::sampling::categorical_from_uniform`: when fp slack
    leaves no index selected (u > total mass), fall back to the last
    index carrying positive mass.
    """
    v = probs.shape[-1]
    cum = jnp.cumsum(probs, axis=-1)
    hit = cum >= u[..., None] if u.ndim else cum >= u
    first = jnp.argmax(hit, axis=-1)
    nz = probs > 0
    last_nz = (v - 1) - jnp.argmax(jnp.flip(nz, axis=-1), axis=-1)
    last_nz = jnp.where(jnp.any(nz, axis=-1), last_nz, v - 1)
    return jnp.where(jnp.any(hit, axis=-1), first, last_nz).astype(jnp.int32)


def temp_softmax(logits: jax.Array, temp: jax.Array) -> jax.Array:
    """Temperature softmax matching `spec::sampling::softmax_t` — same
    per-element op order ((z - max)·inv, then exp) so the two paths can
    only diverge through reduction ordering, not formulation."""
    inv = 1.0 / jnp.maximum(temp, 1e-3)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp((logits - m) * inv)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def draft_q_and_sample(
    logits_c: jax.Array,
    u: jax.Array,
    temp: jax.Array,
    mode: jax.Array,
    vocab_map: jax.Array | None = None,
    full_vocab: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """In-graph draft sampling from (possibly truncated-vocab) logits.

    Args:
      logits_c: [B, Vd] draft logits over the draft vocabulary
      u: [B] host-fed uniforms (consumed only in stochastic mode — the
        host feeds constants and skips its RNG draw for greedy modes)
      vocab_map: [Vd] truncated-index -> full-vocab-index (eagle3), or
        None when the draft emits full-vocab logits

    Returns (token [B] i32 full-vocab ids, q_full [B, V] f32) — the q
    output is consumed by `fused_verify` downstream without ever being
    materialized on the host.
    """
    qc = temp_softmax(logits_c, temp)
    tok_sto = categorical_from_uniform(qc, u)
    tok_greedy = jnp.argmax(qc, axis=-1).astype(jnp.int32)
    tok_c = jnp.where(mode == MODE_STOCHASTIC, tok_sto, tok_greedy)
    if vocab_map is None:
        return tok_c, qc
    b = logits_c.shape[0]
    q_full = (
        jnp.zeros((b, full_vocab), qc.dtype).at[:, vocab_map].set(qc)
    )
    return jnp.take(vocab_map, tok_c), q_full


def _verify_row(
    logits: jax.Array,   # [K+1, V] target logits for the verify block
    q: jax.Array,        # [K, V] full-vocab draft distributions
    drafted: jax.Array,  # [K] i32 full-vocab drafted ids
    u_acc: jax.Array,    # [K] accept uniforms
    u_samp: jax.Array,   # [] sample uniform (residual OR bonus)
    temp: jax.Array,
    mode: jax.Array,
    k_active: jax.Array,  # [] i32: live chain length this round (<= K)
) -> tuple[jax.Array, jax.Array]:
    k1, v = logits.shape
    k = q.shape[0]
    p = temp_softmax(logits, temp)  # [K+1, V]
    pk = p[:k]
    px = jnp.take_along_axis(pk, drafted[:, None], axis=-1)[:, 0]
    qx = jnp.take_along_axis(q, drafted[:, None], axis=-1)[:, 0]
    beta_sto = jnp.minimum(1.0, px / jnp.maximum(qx, 1e-30))
    beta_sto = jnp.where(qx > 0, beta_sto, 0.0)
    beta_gd = jnp.minimum(1.0, px)
    agree = jnp.argmax(pk, axis=-1).astype(jnp.int32) == drafted
    acc_prob = jnp.where(
        mode == MODE_GREEDY,
        agree.astype(p.dtype),
        jnp.where(mode == MODE_GREEDY_DRAFT, beta_gd, beta_sto),
    )
    live = jnp.arange(k, dtype=jnp.int32) < k_active
    acc = (u_acc < acc_prob) & live
    n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32)))
    # Position of the non-draft emission: residual replacement at the
    # first rejection, or the bonus continuation after a clean sweep.
    p_stop = jnp.take(p, n_acc, axis=0)
    q_pad = jnp.concatenate([q, jnp.zeros((k1 - k, v), q.dtype)], axis=0)
    q_stop = jnp.take(q_pad, n_acc, axis=0)
    is_bonus = n_acc >= k_active
    res = jnp.maximum(p_stop - q_stop, 0.0)
    zres = jnp.sum(res)
    # Residual selection thresholds the UNNORMALIZED residual cumsum at
    # u·Z_res — the same formulation as `residual_from_uniform` and the
    # Pallas kernel's phase 2 (equivalent to normalizing first, without
    # introducing a differently-rounded division).
    tok_res = categorical_from_uniform(res, u_samp * zres)
    tok_p = categorical_from_uniform(p_stop, u_samp)
    tok_sampled = jnp.where(
        is_bonus, tok_p, jnp.where(zres > 0, tok_res, tok_p)
    )
    tok_greedy = jnp.argmax(p_stop).astype(jnp.int32)
    token = jnp.where(mode == MODE_GREEDY, tok_greedy, tok_sampled)
    idx = jnp.arange(k1, dtype=jnp.int32)
    drafted_pad = jnp.concatenate(
        [drafted, jnp.zeros((k1 - k,), jnp.int32)], axis=0
    )
    out = jnp.where(idx < n_acc, drafted_pad, 0)
    out = jnp.where(idx == n_acc, token, out)
    return n_acc.astype(jnp.int32), out


def fused_verify(
    logits: jax.Array,   # [B, K+1, V]
    q: jax.Array,        # [B, K, V]
    drafted: jax.Array,  # [B, K] i32
    u_acc: jax.Array,    # [B, K]
    u_samp: jax.Array,   # [B]
    temp: jax.Array,
    mode: jax.Array,
    k_active: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Batched fused softmax + rejection verify + residual/bonus sample.

    Returns (n_acc [B] i32, tokens_out [B, K+1] i32) where
    tokens_out[b, :n_acc[b]] echoes the accepted drafts and
    tokens_out[b, n_acc[b]] is the replacement/bonus emission.
    """
    return jax.vmap(
        _verify_row, in_axes=(0, 0, 0, 0, 0, None, None, None)
    )(logits, q, drafted, u_acc, u_samp, temp, mode, k_active)


def pick_hidden(feats: jax.Array, sel: jax.Array, d: int) -> jax.Array:
    """Per-row gather of the last-d feature slice at index `sel`.

    feats [B, T, F], sel [B] i32 -> [B, d]: the conditioning hidden the
    parallel-head drafts (MEDUSA/MLP) pick up at the accepted-prefix
    boundary — done in-graph so features never reach the host.
    """
    h = jnp.take_along_axis(feats, sel[:, None, None], axis=1)[:, 0, :]
    return h[..., h.shape[-1] - d :]
