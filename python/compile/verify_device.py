"""Device-resident verification & sampling graphs (L1 -> AOT).

The serving engine's historical hot path pulled `[B, K+1, V]` full-vocab
target logits (plus every draft q distribution) to the host each round
and ran softmax + rejection sampling in Rust. The functions here move
that arithmetic in-graph so a decode round returns only O(B*K) integers:
`n_accepted`, the accepted/replacement token ids, and the bonus token.

Randomness stays HOST-OWNED: the engine draws per-position uniforms from
the existing request-keyed PCG64 streams and feeds them in as plain f32
inputs, so a sequence's sample path remains a pure function of
(seed, request id) — batch-composition independence and the scheduler's
continuous-vs-lockstep equivalence tests carry over unchanged.

Shared contract with `rust/src/spec/sampling.rs` (kept in lockstep; the
Rust side documents the same rules):

  * inverse-CDF selection returns the FIRST index with cumsum >= u,
    falling back to the LAST index with positive mass (fp slack);
  * acceptance at position j draws `u_acc[j] < beta_j` with
    beta = min(1, p(x)/q(x)) (stochastic), min(1, p(x)) (greedy-draft,
    the Appendix D bug) or the argmax-agreement indicator (greedy);
  * on the first rejection the replacement is sampled from the
    normalized residual max(p - q, 0) using the round's single sample
    uniform; on full acceptance the bonus token is sampled from p with
    that same uniform (exactly one of the two is consumed per round);
  * mode codes: 0 = greedy, 1 = stochastic, 2 = greedy-draft.

All ops are plain jnp so the graphs AOT-lower portably; the blocked
Pallas realization of the fused round lives in `kernels/fused_verify.py`
and is cross-checked against these functions by `tests/test_kernels.py`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

MODE_GREEDY = 0
MODE_STOCHASTIC = 1
MODE_GREEDY_DRAFT = 2


def categorical_from_uniform(probs: jax.Array, u: jax.Array) -> jax.Array:
    """Inverse-CDF sample: first index with cumsum(probs) >= u.

    Mirrors `spec::sampling::categorical_from_uniform`: when fp slack
    leaves no index selected (u > total mass), fall back to the last
    index carrying positive mass.
    """
    v = probs.shape[-1]
    cum = jnp.cumsum(probs, axis=-1)
    hit = cum >= u[..., None] if u.ndim else cum >= u
    first = jnp.argmax(hit, axis=-1)
    nz = probs > 0
    last_nz = (v - 1) - jnp.argmax(jnp.flip(nz, axis=-1), axis=-1)
    last_nz = jnp.where(jnp.any(nz, axis=-1), last_nz, v - 1)
    return jnp.where(jnp.any(hit, axis=-1), first, last_nz).astype(jnp.int32)


def temp_softmax(logits: jax.Array, temp: jax.Array) -> jax.Array:
    """Temperature softmax matching `spec::sampling::softmax_t` — same
    per-element op order ((z - max)·inv, then exp) so the two paths can
    only diverge through reduction ordering, not formulation."""
    inv = 1.0 / jnp.maximum(temp, 1e-3)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp((logits - m) * inv)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def draft_q_and_sample(
    logits_c: jax.Array,
    u: jax.Array,
    temp: jax.Array,
    mode: jax.Array,
    vocab_map: jax.Array | None = None,
    full_vocab: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """In-graph draft sampling from (possibly truncated-vocab) logits.

    Args:
      logits_c: [B, Vd] draft logits over the draft vocabulary
      u: [B] host-fed uniforms (consumed only in stochastic mode — the
        host feeds constants and skips its RNG draw for greedy modes)
      vocab_map: [Vd] truncated-index -> full-vocab-index (eagle3), or
        None when the draft emits full-vocab logits

    Returns (token [B] i32 full-vocab ids, q_full [B, V] f32) — the q
    output is consumed by `fused_verify` downstream without ever being
    materialized on the host.
    """
    qc = temp_softmax(logits_c, temp)
    tok_sto = categorical_from_uniform(qc, u)
    tok_greedy = jnp.argmax(qc, axis=-1).astype(jnp.int32)
    tok_c = jnp.where(mode == MODE_STOCHASTIC, tok_sto, tok_greedy)
    if vocab_map is None:
        return tok_c, qc
    b = logits_c.shape[0]
    q_full = (
        jnp.zeros((b, full_vocab), qc.dtype).at[:, vocab_map].set(qc)
    )
    return jnp.take(vocab_map, tok_c), q_full


def _verify_row(
    logits: jax.Array,   # [K+1, V] target logits for the verify block
    q: jax.Array,        # [K, V] full-vocab draft distributions
    drafted: jax.Array,  # [K] i32 full-vocab drafted ids
    u_acc: jax.Array,    # [K] accept uniforms
    u_samp: jax.Array,   # [] sample uniform (residual OR bonus)
    temp: jax.Array,
    mode: jax.Array,
    k_active: jax.Array,  # [] i32: live chain length this round (<= K)
) -> tuple[jax.Array, jax.Array]:
    k1, v = logits.shape
    k = q.shape[0]
    p = temp_softmax(logits, temp)  # [K+1, V]
    pk = p[:k]
    px = jnp.take_along_axis(pk, drafted[:, None], axis=-1)[:, 0]
    qx = jnp.take_along_axis(q, drafted[:, None], axis=-1)[:, 0]
    beta_sto = jnp.minimum(1.0, px / jnp.maximum(qx, 1e-30))
    beta_sto = jnp.where(qx > 0, beta_sto, 0.0)
    beta_gd = jnp.minimum(1.0, px)
    agree = jnp.argmax(pk, axis=-1).astype(jnp.int32) == drafted
    acc_prob = jnp.where(
        mode == MODE_GREEDY,
        agree.astype(p.dtype),
        jnp.where(mode == MODE_GREEDY_DRAFT, beta_gd, beta_sto),
    )
    live = jnp.arange(k, dtype=jnp.int32) < k_active
    acc = (u_acc < acc_prob) & live
    n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32)))
    # Position of the non-draft emission: residual replacement at the
    # first rejection, or the bonus continuation after a clean sweep.
    p_stop = jnp.take(p, n_acc, axis=0)
    q_pad = jnp.concatenate([q, jnp.zeros((k1 - k, v), q.dtype)], axis=0)
    q_stop = jnp.take(q_pad, n_acc, axis=0)
    is_bonus = n_acc >= k_active
    res = jnp.maximum(p_stop - q_stop, 0.0)
    zres = jnp.sum(res)
    # Residual selection thresholds the UNNORMALIZED residual cumsum at
    # u·Z_res — the same formulation as `residual_from_uniform` and the
    # Pallas kernel's phase 2 (equivalent to normalizing first, without
    # introducing a differently-rounded division).
    tok_res = categorical_from_uniform(res, u_samp * zres)
    tok_p = categorical_from_uniform(p_stop, u_samp)
    tok_sampled = jnp.where(
        is_bonus, tok_p, jnp.where(zres > 0, tok_res, tok_p)
    )
    tok_greedy = jnp.argmax(p_stop).astype(jnp.int32)
    token = jnp.where(mode == MODE_GREEDY, tok_greedy, tok_sampled)
    idx = jnp.arange(k1, dtype=jnp.int32)
    drafted_pad = jnp.concatenate(
        [drafted, jnp.zeros((k1 - k,), jnp.int32)], axis=0
    )
    out = jnp.where(idx < n_acc, drafted_pad, 0)
    out = jnp.where(idx == n_acc, token, out)
    return n_acc.astype(jnp.int32), out


def fused_verify(
    logits: jax.Array,   # [B, K+1, V]
    q: jax.Array,        # [B, K, V]
    drafted: jax.Array,  # [B, K] i32
    u_acc: jax.Array,    # [B, K]
    u_samp: jax.Array,   # [B]
    temp: jax.Array,
    mode: jax.Array,
    k_active: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Batched fused softmax + rejection verify + residual/bonus sample.

    Returns (n_acc [B] i32, tokens_out [B, K+1] i32) where
    tokens_out[b, :n_acc[b]] echoes the accepted drafts and
    tokens_out[b, n_acc[b]] is the replacement/bonus emission.
    """
    return jax.vmap(
        _verify_row, in_axes=(0, 0, 0, 0, 0, None, None, None)
    )(logits, q, drafted, u_acc, u_samp, temp, mode, k_active)


# ---------------------------------------------------------------------------
# multi-candidate (tree) verification
# ---------------------------------------------------------------------------
#
# Topology contract (kept in lockstep with `spec::sampling::TreeSpec`):
# candidate nodes are indexed 0..N in BFS order; parents[i] is the NODE
# index of i's parent, -1 for root children, so parents is non-decreasing
# with parents[i] < i. The verify block prepends the root: block position
# 0 is last_token, node i sits at block position i + 1, and padding slots
# carry self-parents (inert: a self-parent can never equal the walk's
# `cur`, and parent > cur stops the scan). Per round a live row draws one
# draft uniform per node (propose), one accept uniform per node and ONE
# sample uniform — the same fixed-count stream contract as the chain.


def tree_block_topology(parents_blk: jax.Array, t: int) -> tuple[jax.Array, jax.Array]:
    """Ancestor mask + depths from a block-position parent array.

    parents_blk [T] i32: parent BLOCK position of each block slot; slot 0
    (the root) is its own parent, as are padding slots. Returns
    (anc [T, T] bool — anc[i, j] iff j is i or an ancestor of i within
    the block — and depth [T] i32, root = 0). Walking T-1 parent hops is
    enough for any topology that fits the block.
    """
    idx = jnp.arange(t, dtype=jnp.int32)
    anc = jnp.zeros((t, t), jnp.bool_).at[idx, idx].set(True)
    depth = jnp.zeros((t,), jnp.int32)
    cur = idx
    for _ in range(t - 1):
        nxt = parents_blk[cur]
        depth = depth + (nxt != cur).astype(jnp.int32)
        anc = anc.at[idx, nxt].set(True)
        cur = nxt
    return anc, depth


def _tree_verify_row(
    logits: jax.Array,    # [N+1, V] target logits for the tree block
    q: jax.Array,         # [N, V] per-node full-vocab draft distributions
    drafted: jax.Array,   # [N] i32 full-vocab candidate ids
    parents: jax.Array,   # [N] i32 NODE parents (-1 root; padding = self)
    u_acc: jax.Array,     # [N] accept uniforms (one per node)
    u_samp: jax.Array,    # [] sample uniform (residual OR bonus)
    temp: jax.Array,
    mode: jax.Array,
    n_active: jax.Array,  # [] i32: live node count this round (<= N)
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One row's multi-candidate verify walk — the in-graph twin of
    `spec::sampling::verify_tree_lazy` (same state machine, same
    per-element formulations; see the Rust rustdoc for the rule).

    Returns (n_path [] i32, path [N] i32 accepted node indices padded
    with -1, tokens_out [N+1] i32, stop_blk [] i32 — the block position
    whose hidden conditions the next round).
    """
    n1, v = logits.shape
    n = q.shape[0]
    p = temp_softmax(logits, temp)                       # [N+1, V]
    amax = jnp.argmax(p, axis=-1).astype(jnp.int32)      # [N+1]

    def cond(s):
        i, cur, r, z, zone, npath, path, stop = s
        return (i < jnp.minimum(n, n_active)) & ~stop

    def body(s):
        i, cur, r, z, zone, npath, path, stop = s
        par = parents[i]
        z_eff = jnp.where(zone, 1.0, z)
        exhausted = par > cur                # BFS order: no children left
        is_child = par == cur
        x = drafted[i]
        rx = r[x]
        qi = q[i]
        qx = qi[x]
        beta_sto = jnp.where(
            qx > 0, jnp.minimum(1.0, rx / jnp.maximum(z_eff * qx, 1e-30)), 0.0
        )
        beta_gd = jnp.minimum(1.0, rx / z_eff)
        agree = amax[cur + 1] == x           # pristine-row argmax
        acc_prob = jnp.where(
            mode == MODE_GREEDY,
            agree.astype(r.dtype),
            jnp.where(mode == MODE_GREEDY_DRAFT, beta_gd, beta_sto),
        )
        accept = is_child & (u_acc[i] < acc_prob)
        reject = is_child & ~accept
        r_rej = jnp.maximum(r - z_eff * qi, 0.0)
        r_acc = p[i + 1]                     # pristine row past node i
        r2 = jnp.where(accept, r_acc, jnp.where(reject, r_rej, r))
        z2 = jnp.where(reject, jnp.sum(r_rej), z)
        zone2 = jnp.where(accept, True, jnp.where(reject, False, zone))
        path2 = jnp.where(accept, path.at[npath].set(i), path)
        return (
            i + 1,
            jnp.where(accept, i, cur),
            r2,
            z2,
            zone2,
            npath + accept.astype(jnp.int32),
            path2,
            stop | exhausted,
        )

    state = (
        jnp.int32(0),
        jnp.int32(-1),
        p[0],
        jnp.float32(1.0),
        jnp.bool_(True),
        jnp.int32(0),
        jnp.full((n,), -1, jnp.int32),
        jnp.bool_(False),
    )
    _, cur, r, z, zone, npath, path, _ = jax.lax.while_loop(cond, body, state)

    stop_blk = cur + 1
    p_stop = p[stop_blk]
    z_eff = jnp.where(zone, 1.0, z)
    # Bonus and residual unify: the selection over r thresholded at
    # u·z_eff IS categorical_from_uniform(p_stop, u) when r is pristine
    # (z_eff exactly 1) and the residual selection otherwise.
    tok_r = categorical_from_uniform(r, u_samp * z_eff)
    tok_p = categorical_from_uniform(p_stop, u_samp)
    tok_sampled = jnp.where(z_eff > 0, tok_r, tok_p)
    token = jnp.where(mode == MODE_GREEDY, amax[stop_blk], tok_sampled)

    idx = jnp.arange(n1, dtype=jnp.int32)
    path_pad = jnp.concatenate([path, jnp.zeros((1,), jnp.int32)])
    drafted_at_path = jnp.take(drafted, jnp.clip(path_pad, 0, n - 1))
    out = jnp.where(idx < npath, drafted_at_path, 0)
    out = jnp.where(idx == npath, token, out)
    return npath, path, out, stop_blk


def tree_verify(
    logits: jax.Array,    # [B, N+1, V]
    q: jax.Array,         # [B, N, V]
    drafted: jax.Array,   # [B, N] i32
    parents: jax.Array,   # [N] i32 (shared topology)
    u_acc: jax.Array,     # [B, N]
    u_samp: jax.Array,    # [B]
    temp: jax.Array,
    mode: jax.Array,
    n_active: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched multi-candidate verify: (n_path [B], path [B, N],
    tokens_out [B, N+1], stop_blk [B]) — tokens_out[b, :n_path[b]] echoes
    the accepted path's candidates, tokens_out[b, n_path[b]] is the
    replacement/bonus emission, exactly the chain layout."""
    return jax.vmap(
        _tree_verify_row, in_axes=(0, 0, 0, None, 0, 0, None, None, None)
    )(logits, q, drafted, parents, u_acc, u_samp, temp, mode, n_active)


def kth_argmax(probs: jax.Array, rank: jax.Array, kmax: int) -> jax.Array:
    """rank-th-largest index per row by repeated first-occurrence
    argmax-and-mask — formulated identically to
    `spec::sampling::argmax_rank` so host and device enumerate greedy
    tree candidates in the same order (ties -> lowest index first)."""
    qq = probs
    out = jnp.zeros(probs.shape[:-1], jnp.int32)
    v = probs.shape[-1]
    for j in range(kmax):
        cur = jnp.argmax(qq, axis=-1).astype(jnp.int32)
        out = jnp.where(rank == j, cur, out)
        qq = jnp.where(
            jax.nn.one_hot(cur, v, dtype=jnp.bool_), -jnp.inf, qq
        )
    return out


def tree_draft_sample(
    head_logits: jax.Array,  # [K, B, Vd] per-level draft logits
    u: jax.Array,            # [B, N] per-node draft uniforms
    level: jax.Array,        # [N] i32 head index per node
    rank: jax.Array,         # [N] i32 sibling rank per node
    temp: jax.Array,
    mode: jax.Array,
    n_slots: int,
    rank_max: int,
) -> tuple[jax.Array, list[jax.Array]]:
    """In-graph tree candidate sampling from parallel-head logits.

    Each node draws from its LEVEL's head distribution: stochastic mode
    samples i.i.d. through the node's uniform (exactness of the
    multi-draft rule needs candidates drawn from the per-node q, which
    for parallel heads is the level distribution); the greedy modes take
    the node's sibling-rank-th largest token, giving distinct top-k
    candidates per node. Returns (tokens [B, N] i32, [N] per-node
    full-vocab q tensors) — the q tensors flow straight into
    `verify_tree_fused` without touching the host.
    """
    qh = temp_softmax(head_logits, temp)  # [K, B, Vd]
    toks, qs = [], []
    for i in range(n_slots):
        qn = jnp.take(qh, level[i], axis=0)          # [B, Vd]
        tok_sto = categorical_from_uniform(qn, u[:, i])
        tok_top = kth_argmax(qn, rank[i], rank_max)
        tok = jnp.where(mode == MODE_STOCHASTIC, tok_sto, tok_top)
        toks.append(tok.astype(jnp.int32))
        qs.append(qn)
    return jnp.stack(toks, axis=1), qs


def tree_child_sample(
    logits_c: jax.Array,   # [B, Vd] draft logits at the node's parent
    u: jax.Array,          # [B] the node's draft uniform
    rank: jax.Array,       # [] i32 sibling rank
    temp: jax.Array,
    mode: jax.Array,
    vocab_map: jax.Array | None = None,
    full_vocab: int | None = None,
    rank_max: int = 7,
) -> tuple[jax.Array, jax.Array]:
    """In-graph candidate sampling for ONE tree node from its parent's
    draft logits — the device twin of `EngineCx::sample_draft_tree`:
    stochastic mode samples i.i.d. through the node's uniform, the
    greedy modes take the sibling-rank-th largest token so siblings
    enumerate distinct top-k candidates. Returns (token [B] i32
    full-vocab ids, q_full [B, V]) like `draft_q_and_sample`.
    """
    qc = temp_softmax(logits_c, temp)
    tok_sto = categorical_from_uniform(qc, u)
    tok_rank = kth_argmax(qc, rank, rank_max)
    tok_c = jnp.where(mode == MODE_STOCHASTIC, tok_sto, tok_rank).astype(jnp.int32)
    if vocab_map is None:
        return tok_c, qc
    b = logits_c.shape[0]
    q_full = jnp.zeros((b, full_vocab), qc.dtype).at[:, vocab_map].set(qc)
    return jnp.take(vocab_map, tok_c).astype(jnp.int32), q_full


def tree_root_sample(
    q_full: jax.Array,  # [B, V] full-vocab ROOT distribution (softmaxed)
    u: jax.Array,       # [B] the node's draft uniform
    rank: jax.Array,    # [] i32 sibling rank
    mode: jax.Array,
    rank_max: int = 7,
) -> jax.Array:
    """Level-0 sibling sampling from the extend-produced full-vocab q0.
    Selection over the SCATTERED full-vocab q equals compact-then-map
    (the host path): the vocab map is sorted, so cumsum order and
    argmax-rank order coincide on the support. Returns [B] i32 ids."""
    tok_sto = categorical_from_uniform(q_full, u)
    tok_rank = kth_argmax(q_full, rank, rank_max)
    return jnp.where(mode == MODE_STOCHASTIC, tok_sto, tok_rank).astype(jnp.int32)


def gather_rows(kv: jax.Array, row_map: jax.Array, batch_axis: int) -> jax.Array:
    """Cross-bucket KV row gather: out row i <- kv row row_map[i] along
    `batch_axis` (2 for target KV [L, 2, B, H, S, Dh], 1 for draft KV
    [2, B, H, S, Dh]).

    The scheduler's migration primitive: one call re-packs a whole
    group's cache into a different batch bucket — downshift (4 -> 1),
    upshift (1 -> 4, with row_map repeating a source row to fill the
    padding clones) — without a single KV byte crossing the host.
    Contract pinned bit-for-bit against the strided host reference
    `rust server::kv::gather_rows` by tests/test_kv_gather.py and the
    Rust integration parity test.
    """
    return jnp.take(kv, row_map, axis=batch_axis)


def pick_hidden(feats: jax.Array, sel: jax.Array, d: int) -> jax.Array:
    """Per-row gather of the last-d feature slice at index `sel`.

    feats [B, T, F], sel [B] i32 -> [B, d]: the conditioning hidden the
    parallel-head drafts (MEDUSA/MLP) pick up at the accepted-prefix
    boundary — done in-graph so features never reach the host.
    """
    h = jnp.take_along_axis(feats, sel[:, None, None], axis=1)[:, 0, :]
    return h[..., h.shape[-1] - d :]
