"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: `python/tests/` asserts the Pallas
kernels (run under ``interpret=True``) match these to tight tolerances over
hypothesis-generated shape/dtype/value sweeps, and the closed-form LK
gradients (paper Appendix A) match ``jax.grad`` of these.

Everything here is straightforward, numerically-careful jnp — no tiling,
no online accumulation — so it is easy to audit against the paper's
equations:

  alpha(p, q)   = sum_i min(p_i, q_i)                      (paper eq. 1)
  TV(p, q)      = 0.5 * sum_i |p_i - q_i|
  KL(p, q)      = sum_i p_i log(p_i / q_i)
  L_LK^alpha    = -log alpha                               (paper §4.3)
  L_LK^lambda   = lambda*KL + (1-lambda)*TV                (paper §4.2)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# softmax statistics
# ---------------------------------------------------------------------------

def softmax_stats(z: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Rowwise (max, logsumexp) of logits ``z`` with shape [..., V]."""
    m = jnp.max(z, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(z - m[..., None]), axis=-1))
    return m, lse


def softmax(z: jax.Array) -> jax.Array:
    return jax.nn.softmax(z, axis=-1)


# ---------------------------------------------------------------------------
# LK reduction terms (full-vocabulary case)
# ---------------------------------------------------------------------------

def lk_terms(z_p: jax.Array, z_q: jax.Array) -> dict[str, jax.Array]:
    """Acceptance-rate-family reductions between two logit rows.

    Args:
      z_p: target logits [..., V]
      z_q: draft logits  [..., V]

    Returns dict with rowwise [...]-shaped arrays:
      alpha : sum min(p, q)          -- acceptance rate (eq. 1)
      tv    : 0.5 sum |p - q|        -- total variation (== 1 - alpha)
      kl    : sum p log(p/q)         -- forward KL(p || q)
    """
    p = softmax(z_p)
    q = softmax(z_q)
    alpha = jnp.sum(jnp.minimum(p, q), axis=-1)
    tv = 0.5 * jnp.sum(jnp.abs(p - q), axis=-1)
    # p log(p/q) computed in logit space for stability: log p - log q =
    # (z_p - lse_p) - (z_q - lse_q).
    _, lse_p = softmax_stats(z_p)
    _, lse_q = softmax_stats(z_q)
    logp = z_p - lse_p[..., None]
    logq = z_q - lse_q[..., None]
    kl = jnp.sum(p * (logp - logq), axis=-1)
    return {"alpha": alpha, "tv": tv, "kl": kl}


# ---------------------------------------------------------------------------
# LK reduction terms (truncated draft vocabulary, paper §4.4)
# ---------------------------------------------------------------------------

def lk_terms_truncated(
    z_p_full: jax.Array, z_q: jax.Array, vocab_map: jax.Array
) -> dict[str, jax.Array]:
    """LK terms when the draft head emits logits over a sub-vocabulary.

    The draft distribution q lives on the truncated vocabulary (FR-Spec
    style); outside it q == 0. Per paper §4.4:

      * alpha and TV are computed against the ORIGINAL target distribution
        p (tokens outside the sub-vocab contribute min(p,0)=0 to alpha and
        |p - 0| = p to TV);
      * KL must use the masked/renormalized target p~ = softmax(z_p | sub)
        (otherwise it is infinite) -- the "proxy of a proxy".

    Args:
      z_p_full : [..., V] target logits over the full vocabulary
      z_q      : [..., Vd] draft logits over the truncated vocabulary
      vocab_map: [Vd] int32, truncated-index -> full-vocab-index

    Returns rowwise arrays: alpha, tv, kl, p_in (target mass inside the
    truncated vocabulary).
    """
    p_full = softmax(z_p_full)
    q = softmax(z_q)
    p_sub = jnp.take(p_full, vocab_map, axis=-1)  # [..., Vd], true p on sub
    p_in = jnp.sum(p_sub, axis=-1)
    alpha = jnp.sum(jnp.minimum(p_sub, q), axis=-1)
    # TV against the original p: inside-sub |p - q| plus the mass outside.
    tv = 0.5 * (jnp.sum(jnp.abs(p_sub - q), axis=-1) + (1.0 - p_in))
    # Masked-target KL(p~ || q).
    z_p_sub = jnp.take(z_p_full, vocab_map, axis=-1)
    _, lse_psub = softmax_stats(z_p_sub)
    _, lse_q = softmax_stats(z_q)
    p_tilde = jnp.exp(z_p_sub - lse_psub[..., None])
    kl = jnp.sum(
        p_tilde * ((z_p_sub - lse_psub[..., None]) - (z_q - lse_q[..., None])),
        axis=-1,
    )
    return {"alpha": alpha, "tv": tv, "kl": kl, "p_in": p_in}


# ---------------------------------------------------------------------------
# Closed-form gradients (paper Appendix A) -- the custom-VJP backward path
# ---------------------------------------------------------------------------

def grad_kl(p_tilde: jax.Array, q: jax.Array) -> jax.Array:
    """nabla_{z_q} KL(p~ || q) = q - p~   (A.2)."""
    return q - p_tilde


def grad_tv(p: jax.Array, q: jax.Array) -> jax.Array:
    """nabla_{z_q} TV(p, q) = 0.5 q (s - E_q[s]), s = sign(q - p)  (A.3).

    Valid for the truncated case too (off-support |p| terms carry no z_q
    dependence), with p the true target restricted to the sub-vocabulary.
    """
    s = jnp.sign(q - p)
    es = jnp.sum(q * s, axis=-1, keepdims=True)
    return 0.5 * q * (s - es)


def grad_alpha(p: jax.Array, q: jax.Array) -> jax.Array:
    """nabla_{z_q} alpha = q (a - E_q[a]), a = 1{q < p}.

    Derivation: alpha = sum_i min(p_i, q_i); d min/d q_i = 1{q_i < p_i}
    (subgradient 0 at ties), then chain through the softmax Jacobian.
    Note alpha = 1 - TV so this equals -2*grad_tv up to the tie convention.
    """
    a = (q < p).astype(q.dtype)
    ea = jnp.sum(q * a, axis=-1, keepdims=True)
    return q * (a - ea)


def grad_log_alpha_loss(p: jax.Array, q: jax.Array, alpha: jax.Array) -> jax.Array:
    """nabla_{z_q} (-log alpha) = (1/alpha) nabla_{z_q} TV   (A.4)."""
    return -grad_alpha(p, q) / alpha[..., None]


# ---------------------------------------------------------------------------
# Attention reference
# ---------------------------------------------------------------------------

def causal_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_offset: jax.Array | int,
    kv_len: jax.Array | int,
) -> jax.Array:
    """Masked causal attention used by target & draft blocks.

    Args:
      q: [B, H, Sq, D] queries for absolute positions
         q_offset .. q_offset+Sq-1
      k: [B, H, Sk, D] key buffer; index j holds the key for absolute
         position j (entries beyond the written region are garbage)
      v: [B, H, Sk, D]
      q_offset: scalar, absolute position of q[.., 0, :]
      kv_len: scalar, number of valid kv entries *including* the in-flight
        query block (i.e. total sequence length after this call)

    Query at absolute position t attends to kv index j iff j <= t and
    j < kv_len. Garbage cache entries are excluded because they live at
    indices >= kv_len.
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    sq, sk = q.shape[2], k.shape[2]
    qpos = q_offset + jnp.arange(sq)[:, None]  # [Sq, 1] absolute positions
    jpos = jnp.arange(sk)[None, :]  # [1, Sk]
    mask = (jpos <= qpos) & (jpos < kv_len)
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


# ---------------------------------------------------------------------------
# Verification reference (speculative sampling, Leviathan et al. 2023)
# ---------------------------------------------------------------------------

def verify_probs(
    p: jax.Array, q: jax.Array, drafted: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Acceptance probabilities and residual distributions.

    Args:
      p: [K, V] target probabilities at the K drafted positions
      q: [K, V] draft probabilities at the K drafted positions
      drafted: [K] int32 drafted token ids

    Returns:
      beta: [K] acceptance probability min(1, p(x)/q(x)) for each draft
      residual: [K, V] renormalized max(p - q, 0) to sample on rejection
    """
    px = jnp.take_along_axis(p, drafted[:, None], axis=-1)[:, 0]
    qx = jnp.take_along_axis(q, drafted[:, None], axis=-1)[:, 0]
    beta = jnp.minimum(1.0, px / jnp.maximum(qx, 1e-30))
    res = jnp.maximum(p - q, 0.0)
    norm = jnp.sum(res, axis=-1, keepdims=True)
    # If p == q exactly the residual is empty; fall back to p.
    residual = jnp.where(norm > 0, res / jnp.maximum(norm, 1e-30), p)
    return beta, residual
