"""L1 Pallas kernel: causal flash-style attention.

Used by the target transformer blocks and the EAGLE-3 draft layer. The
schedule is the TPU adaptation of the GPU flash-attention pattern
(DESIGN.md §3): instead of a threadblock per query tile with shared-memory
KV staging, we run a sequential grid over (batch·head, query-block,
kv-block) with the online-softmax accumulators (m, l, o) living in the
revisited output blocks, and BlockSpec expressing the HBM→VMEM staging of
K/V tiles.

Masking is positional: query at absolute position ``q_offset + i`` may
attend to kv index j iff ``j <= pos`` and ``j < kv_len`` — this supports
all three runtime shapes with one kernel:

  * prefill   (q_offset = 0, kv_len = S)
  * verify    (q_offset = ctx, kv block holds ctx + K + 1 entries)
  * decode    (Sq = 1)

``interpret=True`` is mandatory on the CPU PJRT plugin (real-TPU lowering
emits Mosaic custom-calls the CPU client cannot run); numerics are
validated against `ref.causal_attention`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Q_BLOCK = 64
KV_BLOCK = 64

_NEG_BIG = -1e30


def _attn_kernel(
    qoff_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
    *, kv_block: int, scale: float,
):
    """Online-softmax attention over one (bh, q-block) with sequential kv grid.

    Accumulators per query row (live in revisited output blocks):
      m — running max of scores, l — running sum of exp(scores − m),
      o — running weighted value sum, rescaled when m changes.
    """
    kb = pl.program_id(2)
    q = q_ref[...][0]  # [Qb, D]
    k = k_ref[...][0]  # [Kb, D]
    v = v_ref[...][0]  # [Kb, D]
    qoff = qoff_ref[0]
    kvlen = kvlen_ref[0]

    scores = jnp.dot(q, k.T) * scale  # [Qb, Kb]
    qpos = qoff + pl.program_id(1) * q.shape[0] + jax.lax.iota(jnp.int32, q.shape[0])
    jpos = kb * kv_block + jax.lax.iota(jnp.int32, k.shape[0])
    mask = (jpos[None, :] <= qpos[:, None]) & (jpos[None, :] < kvlen)
    scores = jnp.where(mask, scores, _NEG_BIG)
    blk_m = jnp.max(scores, axis=-1)  # [Qb]

    @pl.when(kb == 0)
    def _init():
        e = jnp.exp(scores - blk_m[:, None])
        # Fully-masked rows (qpos < 0 never happens; padding rows handled
        # by caller) still produce finite output via the exp of -BIG.
        m_ref[...] = blk_m[None]
        l_ref[...] = jnp.sum(e, axis=-1)[None]
        o_ref[...] = jnp.dot(e, v)[None]

    @pl.when(kb > 0)
    def _accum():
        m_old = m_ref[...][0]
        l_old = l_ref[...][0]
        o_old = o_ref[...][0]
        m_new = jnp.maximum(m_old, blk_m)
        corr = jnp.exp(m_old - m_new)
        e = jnp.exp(scores - m_new[:, None])
        m_ref[...] = m_new[None]
        l_ref[...] = (l_old * corr + jnp.sum(e, axis=-1))[None]
        o_ref[...] = (o_old * corr[:, None] + jnp.dot(e, v))[None]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_offset: jax.Array | int,
    kv_len: jax.Array | int,
    q_block: int = Q_BLOCK,
    kv_block: int = KV_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """Causal attention [B, H, Sq, D] x [B, H, Sk, D] -> [B, H, Sq, D].

    Matches `ref.causal_attention`. Sq/Sk are padded to tile boundaries by
    the caller; invalid kv entries are excluded via ``kv_len``.
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0, (sq, sk, q_block, kv_block)
    bh = b * h
    q3 = q.reshape(bh, sq, d)
    k3 = k.reshape(bh, sk, d)
    v3 = v.reshape(bh, sk, d)
    qoff = jnp.asarray(q_offset, jnp.int32).reshape(1)
    kvl = jnp.asarray(kv_len, jnp.int32).reshape(1)
    grid = (bh, sq // q_block, sk // kv_block)
    kernel = functools.partial(
        _attn_kernel, kv_block=kv_block, scale=1.0 / float(d) ** 0.5
    )
    scalar_spec = pl.BlockSpec((1,), lambda bhi, qi, ki: (0,))
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            scalar_spec,
            scalar_spec,
            pl.BlockSpec((1, q_block, d), lambda bhi, qi, ki: (bhi, qi, 0)),
            pl.BlockSpec((1, kv_block, d), lambda bhi, qi, ki: (bhi, ki, 0)),
            pl.BlockSpec((1, kv_block, d), lambda bhi, qi, ki: (bhi, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_block, d), lambda bhi, qi, ki: (bhi, qi, 0)),
            pl.BlockSpec((1, q_block), lambda bhi, qi, ki: (bhi, qi)),
            pl.BlockSpec((1, q_block), lambda bhi, qi, ki: (bhi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), q.dtype),
        ],
        interpret=interpret,
    )(qoff, kvl, q3, k3, v3)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, sq, d)
