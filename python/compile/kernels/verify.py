"""L1 Pallas kernel: speculative-verification probabilities.

Computes, for the K drafted positions of one verification round, the
acceptance probabilities beta_i = min(1, p(x_i)/q(x_i)) and the residual
distributions max(p - q, 0)/Z used on rejection (Leviathan et al. 2023,
alg. 1). One vocab traversal per row: the gather of p(x)/q(x), the
clipped difference, and the residual normalizer are fused so the residual
never round-trips to HBM unnormalized.

The serving engine's hot path runs this arithmetic in Rust (V=512 rows are
trivial there and the sampling policy lives in L3); the kernel exists so
the *verification math itself* has a first-class, tested L1 implementation
that a real-TPU deployment would call in-graph right after the target
forward, and so python tests can cross-check the Rust implementation via
shared test vectors (tests/data/verify_vectors.json).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8
VOCAB_BLOCK = 128


def _verify_kernel(drafted_ref, p_ref, q_ref, beta_ref, res_ref, znum_ref):
    """Per (row-block, vocab-block): clipped residual + running normalizer.

    beta needs p(x), q(x) at the drafted token — computed via a masked
    reduction over the block that holds the token (avoids dynamic gather,
    which keeps the kernel Mosaic-friendly).
    """
    j = pl.program_id(1)
    p = p_ref[...]  # [Rb, Vb]
    q = q_ref[...]
    drafted = drafted_ref[...]  # [Rb]
    vb = p.shape[1]
    cols = j * vb + jax.lax.iota(jnp.int32, vb)  # absolute vocab ids
    hit = cols[None, :] == drafted[:, None]  # [Rb, Vb]
    px = jnp.sum(jnp.where(hit, p, 0.0), axis=-1)
    qx = jnp.sum(jnp.where(hit, q, 0.0), axis=-1)
    res = jnp.maximum(p - q, 0.0)
    res_ref[...] = res
    blk_z = jnp.sum(res, axis=-1)
    blk_beta = jnp.minimum(1.0, px / jnp.maximum(qx, 1e-30))
    # beta contribution only from the block containing the drafted token;
    # other blocks contribute 0 (px=qx=0 there -> beta=0 by the mask).
    has_hit = jnp.sum(hit.astype(p.dtype), axis=-1)

    @pl.when(j == 0)
    def _init():
        znum_ref[...] = blk_z
        beta_ref[...] = blk_beta * has_hit

    @pl.when(j > 0)
    def _accum():
        znum_ref[...] += blk_z
        beta_ref[...] += blk_beta * has_hit


def verify_probs(
    p: jax.Array,
    q: jax.Array,
    drafted: jax.Array,
    vocab_block: int = VOCAB_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(beta[K], residual[K, V]) for drafted tokens. Matches `ref.verify_probs`."""
    kk, v = p.shape
    vocab_block = min(vocab_block, v)
    assert v % vocab_block == 0
    nvb = v // vocab_block
    row_spec = pl.BlockSpec((kk,), lambda i, j: (0,))
    mat_spec = pl.BlockSpec((kk, vocab_block), lambda i, j: (0, j))
    beta, res, znum = pl.pallas_call(
        _verify_kernel,
        grid=(1, nvb),
        in_specs=[row_spec, mat_spec, mat_spec],
        out_specs=[row_spec, mat_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((kk,), p.dtype),
            jax.ShapeDtypeStruct((kk, v), p.dtype),
            jax.ShapeDtypeStruct((kk,), p.dtype),
        ],
        interpret=interpret,
    )(drafted.astype(jnp.int32), p, q)
    norm = znum[:, None]
    residual = jnp.where(norm > 0, res / jnp.maximum(norm, 1e-30), p)
    return beta, residual
