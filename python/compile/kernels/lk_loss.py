"""L1 Pallas kernels for the LK-loss family (paper §4, Appendix A).

The compute hot-spot of LK-loss training is a *fused dual-softmax
reduction* over the vocabulary axis: for every (batch, position, head) row
we need logsumexp(z_p), logsumexp(z_q) and then three reductions coupling
the two distributions — Σ min(p,q) (acceptance), Σ|p−q| (TV) and
Σ p̃ log(p̃/q) (KL). A naive implementation materializes five V-sized
intermediates in HBM per row; these kernels stream the logits through
VMEM-resident tiles instead.

Hardware adaptation (DESIGN.md §3): the paper trained on GPUs where this
fusion is a warp-level blockReduce over shared memory. On TPU we express
the same schedule with a sequential grid over (row-block, vocab-block)
tiles and running accumulators that live in the (revisited) output block:

  pass A  `softmax_stats_kernel` — online (m, Σe^{z−m}) per row for z_p
          and z_q (one traversal each);
  pass B  `lk_reduce_kernel`     — one further traversal computing all
          four coupled reductions with p, q reconstructed on the fly from
          logits + normalizers; nothing of size V ever leaves VMEM.

Grid iteration order on TPU is sequential, which makes the
init-on-first-block / accumulate-on-rest pattern sound; ``interpret=True``
(mandatory on the CPU-only PJRT plugin — real-TPU lowering emits Mosaic
custom-calls the CPU client cannot execute) preserves those semantics
exactly, so correctness is validated on CPU and the BlockSpec schedule is
what we carry to real hardware.

All kernels are exposed through `fused_lk_terms` / `fused_softmax_stats`,
which `compile.losses` wraps in a custom-VJP (closed-form backward from
paper Appendix A — see `ref.grad_*`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row/vocab tile sizes. On real TPU these are tuned to the 16 MB VMEM
# budget (see DESIGN.md §7 for the footprint estimate at production
# shapes). On the CPU interpret path each grid step lowers to a
# while-loop iteration, so the AOT defaults collapse the grid (one block
# covers our tiny shapes); python/tests pass small explicit block sizes
# to exercise true multi-block accumulation.
ROW_BLOCK = 4096
VOCAB_BLOCK = 512

_NEG_BIG = -1e30


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pick_block(n: int, want: int) -> int:
    """Largest divisor of n that is <= want (keeps grids exact without
    padding; tile-boundary padding is a real-TPU concern only)."""
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# pass A: online softmax statistics
# ---------------------------------------------------------------------------

def _softmax_stats_kernel(z_ref, m_ref, s_ref, *, nvb: int):
    """Online (running max, running scaled sum-exp) accumulation.

    Grid is (row_blocks, vocab_blocks); vocab is the innermost, sequential
    dimension. The output blocks for a given row block are revisited across
    vocab steps and act as accumulators:

      m_new = max(m, max_j z_j)
      s_new = s * exp(m - m_new) + Σ_j exp(z_j - m_new)

    After the last vocab step, logsumexp = m + log(s).
    """
    j = pl.program_id(1)
    z = z_ref[...]  # [Rb, Vb]
    blk_m = jnp.max(z, axis=-1)  # [Rb]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = blk_m
        s_ref[...] = jnp.sum(jnp.exp(z - blk_m[:, None]), axis=-1)

    @pl.when(j > 0)
    def _accum():
        m_old = m_ref[...]
        s_old = s_ref[...]
        m_new = jnp.maximum(m_old, blk_m)
        s_new = s_old * jnp.exp(m_old - m_new) + jnp.sum(
            jnp.exp(z - m_new[:, None]), axis=-1
        )
        m_ref[...] = m_new
        s_ref[...] = s_new


def fused_softmax_stats(
    z: jax.Array,
    row_block: int = ROW_BLOCK,
    vocab_block: int = VOCAB_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Rowwise (max, logsumexp) of ``z`` [N, V] via the Pallas pass-A kernel.

    V must be a multiple of ``vocab_block`` and N of ``row_block`` — the
    caller (aot/model code) always pads shapes to tile boundaries; tests
    exercise both exact and padded shapes through the public wrappers.
    """
    n, v = z.shape
    row_block = _pick_block(n, row_block)
    vocab_block = _pick_block(v, vocab_block)
    nrb, nvb = n // row_block, v // vocab_block
    kernel = functools.partial(_softmax_stats_kernel, nvb=nvb)
    m, s = pl.pallas_call(
        kernel,
        grid=(nrb, nvb),
        in_specs=[pl.BlockSpec((row_block, vocab_block), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((row_block,), lambda i, j: (i,)),
            pl.BlockSpec((row_block,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), z.dtype),
            jax.ShapeDtypeStruct((n,), z.dtype),
        ],
        interpret=interpret,
    )(z)
    return m, m + jnp.log(s)


# ---------------------------------------------------------------------------
# pass B: fused LK reductions
# ---------------------------------------------------------------------------

def _lk_reduce_kernel(
    zp_ref, zq_ref, lsep_ref, lsepsub_ref, lseq_ref,
    alpha_ref, tv_ref, kl_ref, pin_ref,
):
    """One VMEM traversal computing all coupled reductions.

    Reconstructs p, p̃ and q tile-by-tile from logits and the pass-A
    normalizers, then accumulates:

      alpha += Σ min(p, q)          (acceptance, against ORIGINAL p)
      tv_in += Σ |p − q|            (in-support TV part, against p)
      kl    += Σ p̃ (log p̃ − log q)  (masked-target KL, paper §4.4)
      p_in  += Σ p                  (target mass inside draft vocab)

    For the full-vocabulary case the caller passes lse_p_sub == lse_p so
    p̃ == p and tv/alpha/kl are all against the same p, with p_in → 1.
    """
    j = pl.program_id(1)
    zp = zp_ref[...]
    zq = zq_ref[...]
    logp = zp - lsep_ref[...][:, None]
    logpt = zp - lsepsub_ref[...][:, None]
    logq = zq - lseq_ref[...][:, None]
    p = jnp.exp(logp)
    pt = jnp.exp(logpt)
    q = jnp.exp(logq)

    blk_alpha = jnp.sum(jnp.minimum(p, q), axis=-1)
    blk_tv = jnp.sum(jnp.abs(p - q), axis=-1)
    # p̃ → 0 ⇒ p̃·(logp̃ − logq) → 0; logits are finite so no NaN arises.
    blk_kl = jnp.sum(pt * (logpt - logq), axis=-1)
    blk_pin = jnp.sum(p, axis=-1)

    @pl.when(j == 0)
    def _init():
        alpha_ref[...] = blk_alpha
        tv_ref[...] = blk_tv
        kl_ref[...] = blk_kl
        pin_ref[...] = blk_pin

    @pl.when(j > 0)
    def _accum():
        alpha_ref[...] += blk_alpha
        tv_ref[...] += blk_tv
        kl_ref[...] += blk_kl
        pin_ref[...] += blk_pin


def fused_lk_reduce(
    z_p: jax.Array,
    z_q: jax.Array,
    lse_p: jax.Array,
    lse_p_sub: jax.Array,
    lse_q: jax.Array,
    row_block: int = ROW_BLOCK,
    vocab_block: int = VOCAB_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Pass-B kernel: (alpha, tv_in, kl, p_in) rowwise over [N, V] tiles."""
    n, v = z_p.shape
    assert z_q.shape == (n, v)
    row_block = _pick_block(n, row_block)
    vocab_block = _pick_block(v, vocab_block)
    nrb, nvb = n // row_block, v // vocab_block
    row_spec = pl.BlockSpec((row_block,), lambda i, j: (i,))
    mat_spec = pl.BlockSpec((row_block, vocab_block), lambda i, j: (i, j))
    outs = pl.pallas_call(
        _lk_reduce_kernel,
        grid=(nrb, nvb),
        in_specs=[mat_spec, mat_spec, row_spec, row_spec, row_spec],
        out_specs=[row_spec, row_spec, row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((n,), z_p.dtype)] * 4,
        interpret=interpret,
    )(z_p, z_q, lse_p, lse_p_sub, lse_q)
    return tuple(outs)


# ---------------------------------------------------------------------------
# public fused entrypoints
# ---------------------------------------------------------------------------

def fused_lk_terms(
    z_p: jax.Array, z_q: jax.Array, interpret: bool = True
) -> dict[str, jax.Array]:
    """Full-vocabulary LK terms via the two-pass Pallas pipeline.

    Matches `ref.lk_terms` (tested): returns rowwise alpha, tv, kl.
    Accepts [..., V]; leading dims are flattened into the row axis.
    """
    shape = z_p.shape[:-1]
    v = z_p.shape[-1]
    zp2 = z_p.reshape(-1, v)
    zq2 = z_q.reshape(-1, v)
    _, lse_p = fused_softmax_stats(zp2, interpret=interpret)
    _, lse_q = fused_softmax_stats(zq2, interpret=interpret)
    alpha, tv_in, kl, _ = fused_lk_reduce(
        zp2, zq2, lse_p, lse_p, lse_q, interpret=interpret
    )
    return {
        "alpha": alpha.reshape(shape),
        "tv": (0.5 * tv_in).reshape(shape),
        "kl": kl.reshape(shape),
    }


def fused_lk_terms_truncated(
    z_p_full: jax.Array,
    z_q: jax.Array,
    vocab_map: jax.Array,
    interpret: bool = True,
) -> dict[str, jax.Array]:
    """Truncated-vocabulary LK terms (paper §4.4) via the Pallas pipeline.

    alpha/tv measured against the ORIGINAL target distribution (normalizer
    lse over the full vocab); KL against the masked target p̃ (normalizer
    over the sub-vocab). Matches `ref.lk_terms_truncated`.
    """
    shape = z_p_full.shape[:-1]
    v_full = z_p_full.shape[-1]
    vd = z_q.shape[-1]
    zp_full2 = z_p_full.reshape(-1, v_full)
    zq2 = z_q.reshape(-1, vd)
    zp_sub2 = jnp.take(zp_full2, vocab_map, axis=-1)
    _, lse_p_full = fused_softmax_stats(zp_full2, interpret=interpret)
    _, lse_p_sub = fused_softmax_stats(zp_sub2, interpret=interpret)
    _, lse_q = fused_softmax_stats(zq2, interpret=interpret)
    alpha, tv_in, kl, p_in = fused_lk_reduce(
        zp_sub2, zq2, lse_p_full, lse_p_sub, lse_q, interpret=interpret
    )
    tv = 0.5 * (tv_in + (1.0 - p_in))
    return {
        "alpha": alpha.reshape(shape),
        "tv": tv.reshape(shape),
        "kl": kl.reshape(shape),
        "p_in": p_in.reshape(shape),
    }
