"""L1 Pallas kernel: fused softmax + rejection verify + residual sampling.

The device-resident verify pipeline (see `compile.verify_device` for the
layer contract and the pure-jnp serving graphs) replaces the serving
engine's per-round `[K+1, V]` logits round-trip with O(K) verdicts. This
module is the blocked Pallas realization of that round for one sequence:
everything of size V — the temperature softmax, the p(x)/q(x) gathers,
the residual mass and both inverse-CDF selections — streams through
VMEM tiles in three sequential phases over the vocabulary axis, and only
[K+1]-sized statistics ever land in HBM:

  phase 0  online softmax stats (running max / scaled sum-exp), the
           z(x), q(x) gathers at the drafted tokens and the running
           argmax (greedy mode);
  phase 1  with the normalizers final: residual mass Σ max(p−q, 0) and
           the inverse-CDF selection over p (the bonus / fallback
           sample) with a running-cumsum carry;
  phase 2  with the residual mass final: the inverse-CDF selection over
           the *unnormalized* residual against the threshold u·Z_res
           (equivalent to normalizing, without materializing it).

The [K+1]-level epilogue (accept chain, mode dispatch, token scatter) is
plain jnp — it is O(K) work. Selection semantics match
`verify_device.categorical_from_uniform` and the Rust host path: first
index with cumsum >= u, else the last index with positive mass.

As with the other kernels, grid iteration is sequential so the
init-on-first-block / accumulate-on-rest pattern is sound, and
``interpret=True`` is mandatory on the CPU-only PJRT plugin; tests
cross-check against `verify_device.fused_verify` on multi-block grids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import verify_device as VD

VOCAB_BLOCK = 128


def _pick_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return b


def _fused_verify_kernel(
    z_ref, q_ref, drafted_ref, u_ref, inv_ref,
    m_ref, s_ref, zx_ref, qx_ref, amax_ref,
    zres_ref, cump_ref, cumr_ref,
    selp_ref, lastp_ref, selr_ref, lastr_ref,
    *, vb: int,
):
    """Three sequential vocab traversals with [K+1]-sized carries.

    Grid is (3, vocab_blocks); all outputs use the same revisited row
    block, so they persist as accumulators across both grid dimensions.
    Probabilities are formed as exp((z - m)·inv) — subtract-then-scale,
    the same per-element order as `spec::sampling::softmax_t` and
    `verify_device.temp_softmax`.
    """
    ph = pl.program_id(0)
    j = pl.program_id(1)
    z = z_ref[...]        # [K1, Vb] raw logits
    q = q_ref[...]        # [K1, Vb] draft probs (zero row appended for K)
    drafted = drafted_ref[...]  # [K1]
    u = u_ref[...]        # [K1] sample uniform (broadcast)
    inv = inv_ref[...]    # [K1] 1/temperature (broadcast)
    cols = j * vb + jax.lax.iota(jnp.int32, vb)

    @pl.when((ph == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], -1e30)
        s_ref[...] = jnp.zeros_like(s_ref[...])
        zx_ref[...] = jnp.zeros_like(zx_ref[...])
        qx_ref[...] = jnp.zeros_like(qx_ref[...])
        amax_ref[...] = jnp.zeros_like(amax_ref[...])
        zres_ref[...] = jnp.zeros_like(zres_ref[...])
        cump_ref[...] = jnp.zeros_like(cump_ref[...])
        cumr_ref[...] = jnp.zeros_like(cumr_ref[...])
        selp_ref[...] = jnp.full_like(selp_ref[...], -1)
        lastp_ref[...] = jnp.full_like(lastp_ref[...], -1)
        selr_ref[...] = jnp.full_like(selr_ref[...], -1)
        lastr_ref[...] = jnp.full_like(lastr_ref[...], -1)

    @pl.when(ph == 0)
    def _stats():
        # Online (m, s) with rescaling; first-occurrence running argmax;
        # masked gathers of z and q at the drafted token.
        m_old = m_ref[...]
        blk_m = jnp.max(z, axis=-1)
        blk_am = jnp.argmax(z, axis=-1).astype(jnp.int32)
        m_new = jnp.maximum(m_old, blk_m)
        s_ref[...] = s_ref[...] * jnp.exp((m_old - m_new) * inv) + jnp.sum(
            jnp.exp((z - m_new[:, None]) * inv[:, None]), axis=-1
        )
        m_ref[...] = m_new
        amax_ref[...] = jnp.where(
            blk_m > m_old, j * vb + blk_am, amax_ref[...]
        )
        hit = cols[None, :] == drafted[:, None]
        zx_ref[...] += jnp.sum(jnp.where(hit, z, 0.0), axis=-1)
        qx_ref[...] += jnp.sum(jnp.where(hit, q, 0.0), axis=-1)

    @pl.when(ph == 1)
    def _mass_and_p_select():
        p = (
            jnp.exp((z - m_ref[...][:, None]) * inv[:, None])
            / s_ref[...][:, None]
        )
        zres_ref[...] += jnp.sum(jnp.maximum(p - q, 0.0), axis=-1)
        c = cump_ref[...][:, None] + jnp.cumsum(p, axis=-1)
        hit = c >= u[:, None]
        any_hit = jnp.any(hit, axis=-1)
        first = j * vb + jnp.argmax(hit, axis=-1).astype(jnp.int32)
        selp_ref[...] = jnp.where(
            (selp_ref[...] < 0) & any_hit, first, selp_ref[...]
        )
        nz = p > 0
        last = j * vb + (vb - 1) - jnp.argmax(
            jnp.flip(nz, axis=-1), axis=-1
        ).astype(jnp.int32)
        lastp_ref[...] = jnp.where(jnp.any(nz, axis=-1), last, lastp_ref[...])
        cump_ref[...] += jnp.sum(p, axis=-1)

    @pl.when(ph == 2)
    def _residual_select():
        p = (
            jnp.exp((z - m_ref[...][:, None]) * inv[:, None])
            / s_ref[...][:, None]
        )
        res = jnp.maximum(p - q, 0.0)
        # Threshold u·Z_res ≡ selecting from the normalized residual.
        t = u * zres_ref[...]
        c = cumr_ref[...][:, None] + jnp.cumsum(res, axis=-1)
        hit = c >= t[:, None]
        any_hit = jnp.any(hit, axis=-1)
        first = j * vb + jnp.argmax(hit, axis=-1).astype(jnp.int32)
        selr_ref[...] = jnp.where(
            (selr_ref[...] < 0) & any_hit, first, selr_ref[...]
        )
        nz = res > 0
        last = j * vb + (vb - 1) - jnp.argmax(
            jnp.flip(nz, axis=-1), axis=-1
        ).astype(jnp.int32)
        lastr_ref[...] = jnp.where(jnp.any(nz, axis=-1), last, lastr_ref[...])
        cumr_ref[...] += jnp.sum(res, axis=-1)


def fused_verify_row(
    logits: jax.Array,   # [K+1, V] target logits for the verify block
    q: jax.Array,        # [K, V] full-vocab draft distributions
    drafted: jax.Array,  # [K] i32 drafted token ids
    u_acc: jax.Array,    # [K] accept uniforms
    u_samp: jax.Array,   # [] sample uniform
    temp: jax.Array,
    mode: jax.Array,
    k_active: jax.Array,
    vocab_block: int = VOCAB_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One sequence's fused verify round; matches
    `verify_device._verify_row` (tested)."""
    k1, v = logits.shape
    k = q.shape[0]
    vb = _pick_block(v, vocab_block)
    nvb = v // vb
    z = logits
    inv = 1.0 / jnp.maximum(temp, 1e-3)
    inv_full = jnp.broadcast_to(inv, (k1,)).astype(z.dtype)
    q_pad = jnp.concatenate([q, jnp.zeros((k1 - k, v), q.dtype)], axis=0)
    drafted_pad = jnp.concatenate(
        [drafted.astype(jnp.int32), jnp.zeros((k1 - k,), jnp.int32)], axis=0
    )
    u_full = jnp.broadcast_to(u_samp, (k1,)).astype(z.dtype)
    row_spec = pl.BlockSpec((k1,), lambda ph, j: (0,))
    mat_spec = pl.BlockSpec((k1, vb), lambda ph, j: (0, j))
    f = jax.ShapeDtypeStruct((k1,), z.dtype)
    i = jax.ShapeDtypeStruct((k1,), jnp.int32)
    kernel = functools.partial(_fused_verify_kernel, vb=vb)
    (m, s, zx, qx, amax, zres, _cp, _cr, selp, lastp, selr, lastr) = (
        pl.pallas_call(
            kernel,
            grid=(3, nvb),
            in_specs=[mat_spec, mat_spec, row_spec, row_spec, row_spec],
            out_specs=[row_spec] * 5 + [row_spec] * 3 + [row_spec] * 4,
            out_shape=[f, f, f, f, i, f, f, f, i, i, i, i],
            interpret=interpret,
        )(z, q_pad, drafted_pad, u_full, inv_full)
    )

    # [K+1]-level epilogue: accept chain + mode dispatch + token scatter.
    px = jnp.exp((zx - m) * inv) / s
    sel_p = jnp.where(selp >= 0, selp, jnp.where(lastp >= 0, lastp, v - 1))
    sel_r = jnp.where(selr >= 0, selr, jnp.where(lastr >= 0, lastr, v - 1))
    res_sample = jnp.where(zres > 0, sel_r, sel_p)

    pxk, qxk = px[:k], qx[:k]
    beta_sto = jnp.where(
        qxk > 0, jnp.minimum(1.0, pxk / jnp.maximum(qxk, 1e-30)), 0.0
    )
    beta_gd = jnp.minimum(1.0, pxk)
    agree = amax[:k] == drafted.astype(jnp.int32)
    acc_prob = jnp.where(
        mode == VD.MODE_GREEDY,
        agree.astype(z.dtype),
        jnp.where(mode == VD.MODE_GREEDY_DRAFT, beta_gd, beta_sto),
    )
    live = jnp.arange(k, dtype=jnp.int32) < k_active
    acc = (u_acc < acc_prob) & live
    n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32)))
    is_bonus = n_acc >= k_active
    tok_sampled = jnp.where(
        is_bonus, jnp.take(sel_p, n_acc), jnp.take(res_sample, n_acc)
    )
    token = jnp.where(
        mode == VD.MODE_GREEDY, jnp.take(amax, n_acc), tok_sampled
    ).astype(jnp.int32)
    idx = jnp.arange(k1, dtype=jnp.int32)
    out = jnp.where(idx < n_acc, drafted_pad, 0)
    out = jnp.where(idx == n_acc, token, out)
    return n_acc.astype(jnp.int32), out


def fused_verify(
    logits: jax.Array,
    q: jax.Array,
    drafted: jax.Array,
    u_acc: jax.Array,
    u_samp: jax.Array,
    temp: jax.Array,
    mode: jax.Array,
    k_active: jax.Array,
    vocab_block: int = VOCAB_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched fused verify: [B, K+1, V] in, (n_acc [B], tokens [B, K+1])
    out. Matches `verify_device.fused_verify`."""
    row = functools.partial(
        fused_verify_row, vocab_block=vocab_block, interpret=interpret
    )
    return jax.vmap(row, in_axes=(0, 0, 0, 0, 0, None, None, None))(
        logits, q, drafted, u_acc, u_samp, temp, mode, k_active
    )
