"""L1 Pallas kernel: fused softmax + rejection verify + residual sampling.

The device-resident verify pipeline (see `compile.verify_device` for the
layer contract and the pure-jnp serving graphs) replaces the serving
engine's per-round `[K+1, V]` logits round-trip with O(K) verdicts. This
module is the blocked Pallas realization of that round for one sequence:
everything of size V — the temperature softmax, the p(x)/q(x) gathers,
the residual mass and both inverse-CDF selections — streams through
VMEM tiles in three sequential phases over the vocabulary axis, and only
[K+1]-sized statistics ever land in HBM:

  phase 0  online softmax stats (running max / scaled sum-exp), the
           z(x), q(x) gathers at the drafted tokens and the running
           argmax (greedy mode);
  phase 1  with the normalizers final: residual mass Σ max(p−q, 0) and
           the inverse-CDF selection over p (the bonus / fallback
           sample) with a running-cumsum carry;
  phase 2  with the residual mass final: the inverse-CDF selection over
           the *unnormalized* residual against the threshold u·Z_res
           (equivalent to normalizing, without materializing it).

The [K+1]-level epilogue (accept chain, mode dispatch, token scatter) is
plain jnp — it is O(K) work. Selection semantics match
`verify_device.categorical_from_uniform` and the Rust host path: first
index with cumsum >= u, else the last index with positive mass.

As with the other kernels, grid iteration is sequential so the
init-on-first-block / accumulate-on-rest pattern is sound, and
``interpret=True`` is mandatory on the CPU-only PJRT plugin; tests
cross-check against `verify_device.fused_verify` on multi-block grids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import verify_device as VD

VOCAB_BLOCK = 128


def _pick_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return b


def _fused_verify_kernel(
    z_ref, q_ref, drafted_ref, u_ref, inv_ref,
    m_ref, s_ref, zx_ref, qx_ref, amax_ref,
    zres_ref, cump_ref, cumr_ref,
    selp_ref, lastp_ref, selr_ref, lastr_ref,
    *, vb: int,
):
    """Three sequential vocab traversals with [K+1]-sized carries.

    Grid is (3, vocab_blocks); all outputs use the same revisited row
    block, so they persist as accumulators across both grid dimensions.
    Probabilities are formed as exp((z - m)·inv) — subtract-then-scale,
    the same per-element order as `spec::sampling::softmax_t` and
    `verify_device.temp_softmax`.
    """
    ph = pl.program_id(0)
    j = pl.program_id(1)
    z = z_ref[...]        # [K1, Vb] raw logits
    q = q_ref[...]        # [K1, Vb] draft probs (zero row appended for K)
    drafted = drafted_ref[...]  # [K1]
    u = u_ref[...]        # [K1] sample uniform (broadcast)
    inv = inv_ref[...]    # [K1] 1/temperature (broadcast)
    cols = j * vb + jax.lax.iota(jnp.int32, vb)

    @pl.when((ph == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], -1e30)
        s_ref[...] = jnp.zeros_like(s_ref[...])
        zx_ref[...] = jnp.zeros_like(zx_ref[...])
        qx_ref[...] = jnp.zeros_like(qx_ref[...])
        amax_ref[...] = jnp.zeros_like(amax_ref[...])
        zres_ref[...] = jnp.zeros_like(zres_ref[...])
        cump_ref[...] = jnp.zeros_like(cump_ref[...])
        cumr_ref[...] = jnp.zeros_like(cumr_ref[...])
        selp_ref[...] = jnp.full_like(selp_ref[...], -1)
        lastp_ref[...] = jnp.full_like(lastp_ref[...], -1)
        selr_ref[...] = jnp.full_like(selr_ref[...], -1)
        lastr_ref[...] = jnp.full_like(lastr_ref[...], -1)

    @pl.when(ph == 0)
    def _stats():
        # Online (m, s) with rescaling; first-occurrence running argmax;
        # masked gathers of z and q at the drafted token.
        m_old = m_ref[...]
        blk_m = jnp.max(z, axis=-1)
        blk_am = jnp.argmax(z, axis=-1).astype(jnp.int32)
        m_new = jnp.maximum(m_old, blk_m)
        s_ref[...] = s_ref[...] * jnp.exp((m_old - m_new) * inv) + jnp.sum(
            jnp.exp((z - m_new[:, None]) * inv[:, None]), axis=-1
        )
        m_ref[...] = m_new
        amax_ref[...] = jnp.where(
            blk_m > m_old, j * vb + blk_am, amax_ref[...]
        )
        hit = cols[None, :] == drafted[:, None]
        zx_ref[...] += jnp.sum(jnp.where(hit, z, 0.0), axis=-1)
        qx_ref[...] += jnp.sum(jnp.where(hit, q, 0.0), axis=-1)

    @pl.when(ph == 1)
    def _mass_and_p_select():
        p = (
            jnp.exp((z - m_ref[...][:, None]) * inv[:, None])
            / s_ref[...][:, None]
        )
        zres_ref[...] += jnp.sum(jnp.maximum(p - q, 0.0), axis=-1)
        c = cump_ref[...][:, None] + jnp.cumsum(p, axis=-1)
        hit = c >= u[:, None]
        any_hit = jnp.any(hit, axis=-1)
        first = j * vb + jnp.argmax(hit, axis=-1).astype(jnp.int32)
        selp_ref[...] = jnp.where(
            (selp_ref[...] < 0) & any_hit, first, selp_ref[...]
        )
        nz = p > 0
        last = j * vb + (vb - 1) - jnp.argmax(
            jnp.flip(nz, axis=-1), axis=-1
        ).astype(jnp.int32)
        lastp_ref[...] = jnp.where(jnp.any(nz, axis=-1), last, lastp_ref[...])
        cump_ref[...] += jnp.sum(p, axis=-1)

    @pl.when(ph == 2)
    def _residual_select():
        p = (
            jnp.exp((z - m_ref[...][:, None]) * inv[:, None])
            / s_ref[...][:, None]
        )
        res = jnp.maximum(p - q, 0.0)
        # Threshold u·Z_res ≡ selecting from the normalized residual.
        t = u * zres_ref[...]
        c = cumr_ref[...][:, None] + jnp.cumsum(res, axis=-1)
        hit = c >= t[:, None]
        any_hit = jnp.any(hit, axis=-1)
        first = j * vb + jnp.argmax(hit, axis=-1).astype(jnp.int32)
        selr_ref[...] = jnp.where(
            (selr_ref[...] < 0) & any_hit, first, selr_ref[...]
        )
        nz = res > 0
        last = j * vb + (vb - 1) - jnp.argmax(
            jnp.flip(nz, axis=-1), axis=-1
        ).astype(jnp.int32)
        lastr_ref[...] = jnp.where(jnp.any(nz, axis=-1), last, lastr_ref[...])
        cumr_ref[...] += jnp.sum(res, axis=-1)


def fused_verify_row(
    logits: jax.Array,   # [K+1, V] target logits for the verify block
    q: jax.Array,        # [K, V] full-vocab draft distributions
    drafted: jax.Array,  # [K] i32 drafted token ids
    u_acc: jax.Array,    # [K] accept uniforms
    u_samp: jax.Array,   # [] sample uniform
    temp: jax.Array,
    mode: jax.Array,
    k_active: jax.Array,
    vocab_block: int = VOCAB_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One sequence's fused verify round; matches
    `verify_device._verify_row` (tested)."""
    k1, v = logits.shape
    k = q.shape[0]
    vb = _pick_block(v, vocab_block)
    nvb = v // vb
    z = logits
    inv = 1.0 / jnp.maximum(temp, 1e-3)
    inv_full = jnp.broadcast_to(inv, (k1,)).astype(z.dtype)
    q_pad = jnp.concatenate([q, jnp.zeros((k1 - k, v), q.dtype)], axis=0)
    drafted_pad = jnp.concatenate(
        [drafted.astype(jnp.int32), jnp.zeros((k1 - k,), jnp.int32)], axis=0
    )
    u_full = jnp.broadcast_to(u_samp, (k1,)).astype(z.dtype)
    row_spec = pl.BlockSpec((k1,), lambda ph, j: (0,))
    mat_spec = pl.BlockSpec((k1, vb), lambda ph, j: (0, j))
    f = jax.ShapeDtypeStruct((k1,), z.dtype)
    i = jax.ShapeDtypeStruct((k1,), jnp.int32)
    kernel = functools.partial(_fused_verify_kernel, vb=vb)
    (m, s, zx, qx, amax, zres, _cp, _cr, selp, lastp, selr, lastr) = (
        pl.pallas_call(
            kernel,
            grid=(3, nvb),
            in_specs=[mat_spec, mat_spec, row_spec, row_spec, row_spec],
            out_specs=[row_spec] * 5 + [row_spec] * 3 + [row_spec] * 4,
            out_shape=[f, f, f, f, i, f, f, f, i, i, i, i],
            interpret=interpret,
        )(z, q_pad, drafted_pad, u_full, inv_full)
    )

    # [K+1]-level epilogue: accept chain + mode dispatch + token scatter.
    px = jnp.exp((zx - m) * inv) / s
    sel_p = jnp.where(selp >= 0, selp, jnp.where(lastp >= 0, lastp, v - 1))
    sel_r = jnp.where(selr >= 0, selr, jnp.where(lastr >= 0, lastr, v - 1))
    res_sample = jnp.where(zres > 0, sel_r, sel_p)

    pxk, qxk = px[:k], qx[:k]
    beta_sto = jnp.where(
        qxk > 0, jnp.minimum(1.0, pxk / jnp.maximum(qxk, 1e-30)), 0.0
    )
    beta_gd = jnp.minimum(1.0, pxk)
    agree = amax[:k] == drafted.astype(jnp.int32)
    acc_prob = jnp.where(
        mode == VD.MODE_GREEDY,
        agree.astype(z.dtype),
        jnp.where(mode == VD.MODE_GREEDY_DRAFT, beta_gd, beta_sto),
    )
    live = jnp.arange(k, dtype=jnp.int32) < k_active
    acc = (u_acc < acc_prob) & live
    n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32)))
    is_bonus = n_acc >= k_active
    tok_sampled = jnp.where(
        is_bonus, jnp.take(sel_p, n_acc), jnp.take(res_sample, n_acc)
    )
    token = jnp.where(
        mode == VD.MODE_GREEDY, jnp.take(amax, n_acc), tok_sampled
    ).astype(jnp.int32)
    idx = jnp.arange(k1, dtype=jnp.int32)
    out = jnp.where(idx < n_acc, drafted_pad, 0)
    out = jnp.where(idx == n_acc, token, out)
    return n_acc.astype(jnp.int32), out


def fused_verify(
    logits: jax.Array,
    q: jax.Array,
    drafted: jax.Array,
    u_acc: jax.Array,
    u_samp: jax.Array,
    temp: jax.Array,
    mode: jax.Array,
    k_active: jax.Array,
    vocab_block: int = VOCAB_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched fused verify: [B, K+1, V] in, (n_acc [B], tokens [B, K+1])
    out. Matches `verify_device.fused_verify`."""
    row = functools.partial(
        fused_verify_row, vocab_block=vocab_block, interpret=interpret
    )
    return jax.vmap(row, in_axes=(0, 0, 0, 0, 0, None, None, None))(
        logits, q, drafted, u_acc, u_samp, temp, mode, k_active
    )


# ---------------------------------------------------------------------------
# multi-candidate (tree) verify
# ---------------------------------------------------------------------------
#
# Blocked realization of `verify_device._tree_verify_row` for one
# sequence, reusing the chain kernel's structure: every vocab-sized
# object streams through VMEM tiles, only the walk's state lands in HBM.
# The residual walk is data-dependent, so the grid grows one phase per
# node slot:
#
#   phase 0        online softmax stats (m, s, running argmax) for the
#                  T = N+1 block rows plus the q(x) gathers per node;
#   phase 1        with the normalizers final: materialize the root's
#                  residual r = p[0] and gather r(x_0);
#   phase 2+i      scan step for node i: the O(1) accept/reject/skip
#                  decision happens once (first vocab block) from the
#                  carries, then every block applies the residual update
#                  r <- max(r - z·q_i, 0) (reject) or the pristine-row
#                  reset r <- p[i+1] (accept), accumulating the new mass
#                  and the next candidate's r(x_{i+1}) gather in the same
#                  pass;
#   final phase    the two inverse-CDF selections with running-cumsum
#                  carries: over r at threshold u·z (the unified
#                  residual/bonus emission) and over the pristine stop
#                  row at u (the empty-residual fallback).
#
# The [T]-level epilogue (mode dispatch, token scatter) is plain jnp.


def _sread(ref):
    return ref[...][0]


def _swrite(ref, v):
    ref[...] = jnp.reshape(v, (1,)).astype(ref.dtype)


def _tree_verify_kernel(
    z_ref, q_ref, drafted_ref, parents_ref, uacc_ref, usamp_ref, inv_ref,
    mode_ref, nact_ref,
    m_ref, s_ref, amax_ref, qx_ref, r_ref,
    rx_ref, z_c_ref, zeff_ref, zone_ref, cur_ref, npath_ref, path_ref,
    stop_ref, dec_ref,
    cumr_ref, cump_ref, selr_ref, lastr_ref, selp_ref, lastp_ref, thr_ref,
    *, vb: int, n: int,
):
    ph = pl.program_id(0)
    j = pl.program_id(1)
    z = z_ref[...]            # [T, Vb] raw logits tile
    q = q_ref[...]            # [T, Vb] draft probs (zero row appended)
    drafted = drafted_ref[...]
    parents = parents_ref[...]
    inv = inv_ref[...]
    cols = j * vb + jax.lax.iota(jnp.int32, vb)

    @pl.when((ph == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], -1e30)
        s_ref[...] = jnp.zeros_like(s_ref[...])
        amax_ref[...] = jnp.zeros_like(amax_ref[...])
        qx_ref[...] = jnp.zeros_like(qx_ref[...])
        rx_ref[...] = jnp.zeros_like(rx_ref[...])
        z_c_ref[...] = jnp.zeros_like(z_c_ref[...])
        zeff_ref[...] = jnp.ones_like(zeff_ref[...])
        zone_ref[...] = jnp.ones_like(zone_ref[...])
        cur_ref[...] = jnp.full_like(cur_ref[...], -1)
        npath_ref[...] = jnp.zeros_like(npath_ref[...])
        path_ref[...] = jnp.full_like(path_ref[...], -1)
        stop_ref[...] = jnp.zeros_like(stop_ref[...])
        dec_ref[...] = jnp.zeros_like(dec_ref[...])
        cumr_ref[...] = jnp.zeros_like(cumr_ref[...])
        cump_ref[...] = jnp.zeros_like(cump_ref[...])
        selr_ref[...] = jnp.full_like(selr_ref[...], -1)
        lastr_ref[...] = jnp.full_like(lastr_ref[...], -1)
        selp_ref[...] = jnp.full_like(selp_ref[...], -1)
        lastp_ref[...] = jnp.full_like(lastp_ref[...], -1)
        thr_ref[...] = jnp.zeros_like(thr_ref[...])

    @pl.when(ph == 0)
    def _stats():
        m_old = m_ref[...]
        blk_m = jnp.max(z, axis=-1)
        blk_am = jnp.argmax(z, axis=-1).astype(jnp.int32)
        m_new = jnp.maximum(m_old, blk_m)
        s_ref[...] = s_ref[...] * jnp.exp((m_old - m_new) * inv) + jnp.sum(
            jnp.exp((z - m_new[:, None]) * inv[:, None]), axis=-1
        )
        m_ref[...] = m_new
        amax_ref[...] = jnp.where(blk_m > m_old, j * vb + blk_am, amax_ref[...])
        hit = cols[None, :] == drafted[:, None]
        qx_ref[...] += jnp.sum(jnp.where(hit, q, 0.0), axis=-1)

    def p_row(row_idx):
        zr = jnp.take(z, row_idx, axis=0)
        mr = jnp.take(m_ref[...], row_idx)
        sr = jnp.take(s_ref[...], row_idx)
        ir = jnp.take(inv, row_idx)
        return jnp.exp((zr - mr) * ir) / sr

    @pl.when(ph == 1)
    def _init_root_residual():
        r_blk = p_row(jnp.int32(0))
        r_ref[...] = r_blk
        hit = cols == drafted[0]
        rx_ref[...] += jnp.sum(jnp.where(hit, r_blk, 0.0))[None]

    is_step = (ph >= 2) & (ph < 2 + n)
    i = ph - 2  # node slot this step scans

    @pl.when(is_step & (j == 0))
    def _decide():
        stop = _sread(stop_ref)
        cur = _sread(cur_ref)
        par = jnp.take(parents, i)
        nact = nact_ref[...][0]
        scanning = (stop == 0) & (i < nact)
        exhausted = scanning & (par > cur)
        is_child = scanning & (par == cur)
        zone = _sread(zone_ref)
        z_eff = jnp.where(zone == 1, 1.0, _sread(z_c_ref))
        rx = _sread(rx_ref)
        qx_i = jnp.take(qx_ref[...], i)
        x = jnp.take(drafted, i)
        mode = mode_ref[...][0]
        beta_sto = jnp.where(
            qx_i > 0,
            jnp.minimum(1.0, rx / jnp.maximum(z_eff * qx_i, 1e-30)),
            0.0,
        )
        beta_gd = jnp.minimum(1.0, rx / z_eff)
        agree = jnp.take(amax_ref[...], cur + 1) == x
        acc_prob = jnp.where(
            mode == VD.MODE_GREEDY,
            agree.astype(jnp.float32),
            jnp.where(mode == VD.MODE_GREEDY_DRAFT, beta_gd, beta_sto),
        )
        accept = is_child & (jnp.take(uacc_ref[...], i) < acc_prob)
        reject = is_child & ~accept
        _swrite(dec_ref, jnp.where(accept, 1, jnp.where(reject, 2, 0)))
        _swrite(zeff_ref, z_eff)
        stop_new = (stop == 1) | exhausted | (i >= nact)
        _swrite(stop_ref, jnp.where(stop_new, 1, 0))
        npath = _sread(npath_ref)
        path_ref[...] = jnp.where(
            accept, path_ref[...].at[npath].set(i), path_ref[...]
        )
        _swrite(npath_ref, npath + accept.astype(jnp.int32))
        _swrite(cur_ref, jnp.where(accept, i, cur))
        _swrite(zone_ref, jnp.where(accept, 1, jnp.where(reject, 0, zone)))
        _swrite(z_c_ref, jnp.where(reject, 0.0, _sread(z_c_ref)))
        _swrite(rx_ref, 0.0)

    @pl.when(is_step)
    def _step_update():
        dec = _sread(dec_ref)
        z_eff = _sread(zeff_ref)
        r_blk = r_ref[...]
        r_rej = jnp.maximum(r_blk - z_eff * jnp.take(q, i, axis=0), 0.0)
        r_new = jnp.where(
            dec == 1, p_row(i + 1), jnp.where(dec == 2, r_rej, r_blk)
        )
        r_ref[...] = r_new
        z_c_ref[...] += jnp.where(dec == 2, jnp.sum(r_rej), 0.0)[None]
        nxt = jnp.take(drafted, jnp.minimum(i + 1, n))
        rx_ref[...] += jnp.sum(jnp.where(cols == nxt, r_new, 0.0))[None]

    ph_final = 2 + n

    @pl.when((ph == ph_final) & (j == 0))
    def _final_init():
        z_eff = jnp.where(_sread(zone_ref) == 1, 1.0, _sread(z_c_ref))
        _swrite(zeff_ref, z_eff)
        _swrite(thr_ref, usamp_ref[...][0] * z_eff)

    @pl.when(ph == ph_final)
    def _select():
        r_blk = r_ref[...]
        t = _sread(thr_ref)
        c = _sread(cumr_ref) + jnp.cumsum(r_blk)
        hit = c >= t
        any_hit = jnp.any(hit)
        first = j * vb + jnp.argmax(hit).astype(jnp.int32)
        selr_ref[...] = jnp.where(
            (_sread(selr_ref) < 0) & any_hit, first, _sread(selr_ref)
        )[None]
        nz = r_blk > 0
        last = j * vb + (vb - 1) - jnp.argmax(jnp.flip(nz)).astype(jnp.int32)
        lastr_ref[...] = jnp.where(jnp.any(nz), last, _sread(lastr_ref))[None]
        cumr_ref[...] += jnp.sum(r_blk)[None]

        p_stop = p_row(_sread(cur_ref) + 1)
        u = usamp_ref[...][0]
        cp = _sread(cump_ref) + jnp.cumsum(p_stop)
        hitp = cp >= u
        firstp = j * vb + jnp.argmax(hitp).astype(jnp.int32)
        selp_ref[...] = jnp.where(
            (_sread(selp_ref) < 0) & jnp.any(hitp), firstp, _sread(selp_ref)
        )[None]
        nzp = p_stop > 0
        lastp = j * vb + (vb - 1) - jnp.argmax(jnp.flip(nzp)).astype(jnp.int32)
        lastp_ref[...] = jnp.where(jnp.any(nzp), lastp, _sread(lastp_ref))[None]
        cump_ref[...] += jnp.sum(p_stop)[None]


def tree_verify_row(
    logits: jax.Array,    # [N+1, V] target logits for the tree block
    q: jax.Array,         # [N, V] per-node full-vocab draft distributions
    drafted: jax.Array,   # [N] i32 candidate ids
    parents: jax.Array,   # [N] i32 node parents (-1 root; padding = self)
    u_acc: jax.Array,     # [N] accept uniforms
    u_samp: jax.Array,    # [] sample uniform
    temp: jax.Array,
    mode: jax.Array,
    n_active: jax.Array,
    vocab_block: int = VOCAB_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One sequence's blocked tree-verify round; matches
    `verify_device._tree_verify_row` (tested)."""
    k1, v = logits.shape
    n = q.shape[0]
    vb = _pick_block(v, vocab_block)
    nvb = v // vb
    inv = 1.0 / jnp.maximum(temp, 1e-3)
    inv_full = jnp.broadcast_to(inv, (k1,)).astype(logits.dtype)
    q_pad = jnp.concatenate([q, jnp.zeros((k1 - n, v), q.dtype)], axis=0)
    drafted_pad = jnp.concatenate(
        [drafted.astype(jnp.int32), jnp.zeros((k1 - n,), jnp.int32)], axis=0
    )
    # padding slots are their own parents: inert by the topology contract
    parents_pad = jnp.concatenate(
        [
            parents.astype(jnp.int32),
            n + jax.lax.iota(jnp.int32, k1 - n),
        ],
        axis=0,
    )
    uacc_pad = jnp.concatenate(
        [u_acc.astype(logits.dtype), jnp.zeros((k1 - n,), logits.dtype)], axis=0
    )
    usamp_full = jnp.broadcast_to(u_samp, (k1,)).astype(logits.dtype)
    mode_full = jnp.broadcast_to(mode, (k1,)).astype(jnp.int32)
    nact_full = jnp.broadcast_to(n_active, (k1,)).astype(jnp.int32)

    row_spec = pl.BlockSpec((k1,), lambda ph, j: (0,))
    mat_spec = pl.BlockSpec((k1, vb), lambda ph, j: (0, j))
    vec_spec = pl.BlockSpec((vb,), lambda ph, j: (j,))
    one_spec = pl.BlockSpec((1,), lambda ph, j: (0,))
    f_row = jax.ShapeDtypeStruct((k1,), logits.dtype)
    i_row = jax.ShapeDtypeStruct((k1,), jnp.int32)
    f_one = jax.ShapeDtypeStruct((1,), logits.dtype)
    i_one = jax.ShapeDtypeStruct((1,), jnp.int32)
    kernel = functools.partial(_tree_verify_kernel, vb=vb, n=n)
    (
        _m, _s, amax, _qx, _r,
        _rx, _zc, zeff, _zone, cur, npath, path_full, _stop, _dec,
        _cumr, _cump, selr, lastr, selp, lastp, _thr,
    ) = pl.pallas_call(
        kernel,
        grid=(n + 3, nvb),
        in_specs=[
            mat_spec, mat_spec, row_spec, row_spec, row_spec, row_spec,
            row_spec, row_spec, row_spec,
        ],
        out_specs=[
            row_spec, row_spec, row_spec, row_spec, vec_spec,
            one_spec, one_spec, one_spec, one_spec, one_spec, one_spec,
            row_spec, one_spec, one_spec,
            one_spec, one_spec, one_spec, one_spec, one_spec, one_spec,
            one_spec,
        ],
        out_shape=[
            f_row, f_row, i_row, f_row, jax.ShapeDtypeStruct((v,), logits.dtype),
            f_one, f_one, f_one, i_one, i_one, i_one,
            i_row, i_one, i_one,
            f_one, f_one, i_one, i_one, i_one, i_one,
            f_one,
        ],
        interpret=interpret,
    )(
        logits, q_pad, drafted_pad, parents_pad, uacc_pad, usamp_full,
        inv_full, mode_full, nact_full,
    )

    # [T]-level epilogue: emission dispatch + token scatter.
    cur = cur[0]
    npath = npath[0]
    zeff = zeff[0]
    sel_r = jnp.where(selr[0] >= 0, selr[0], jnp.where(lastr[0] >= 0, lastr[0], v - 1))
    sel_p = jnp.where(selp[0] >= 0, selp[0], jnp.where(lastp[0] >= 0, lastp[0], v - 1))
    tok_sampled = jnp.where(zeff > 0, sel_r, sel_p)
    stop_blk = cur + 1
    token = jnp.where(
        mode == VD.MODE_GREEDY, jnp.take(amax, stop_blk), tok_sampled
    ).astype(jnp.int32)
    path = path_full[:n]
    idx = jnp.arange(k1, dtype=jnp.int32)
    path_pad = jnp.concatenate([path, jnp.zeros((1,), jnp.int32)])
    drafted_at_path = jnp.take(drafted.astype(jnp.int32), jnp.clip(path_pad, 0, n - 1))
    out = jnp.where(idx < npath, drafted_at_path, 0)
    out = jnp.where(idx == npath, token, out)
    return npath, path, out, stop_blk


def tree_verify(
    logits: jax.Array,
    q: jax.Array,
    drafted: jax.Array,
    parents: jax.Array,
    u_acc: jax.Array,
    u_samp: jax.Array,
    temp: jax.Array,
    mode: jax.Array,
    n_active: jax.Array,
    vocab_block: int = VOCAB_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched blocked tree verify: [B, N+1, V] in,
    (n_path [B], path [B, N], tokens [B, N+1], stop_blk [B]) out. Matches
    `verify_device.tree_verify`."""
    row = functools.partial(
        tree_verify_row, vocab_block=vocab_block, interpret=interpret
    )
    return jax.vmap(row, in_axes=(0, 0, 0, None, 0, 0, None, None, None))(
        logits, q, drafted, parents, u_acc, u_samp, temp, mode, n_active
    )
