"""L2: the four draft/speculator architectures (paper §5.2, App. E).

  * EAGLE-3   — single transformer block over fused multi-layer target
                features, recurrent across draft positions, truncated
                output vocabulary (FR-Spec style), frozen target embedding.
  * MTP       — DeepSeek-style multi-token-prediction module: same
                recurrent shape as EAGLE but fuses only the last hidden
                state and shares the target's unembedding; initialized
                from the natively-pretrained module and fine-tuned.
  * MEDUSA    — K independent residual-MLP heads over the last hidden
                state, conditionally-independent parallel prediction.
  * MLP       — multi-stage MLP speculator (Wertheimer et al.): per-head
                recurrent state update from the previous state and the
                embedding of the (sampled / teacher-forced) token.

All are pure functions of explicit parameter pytrees. Training uses the
"training-time test" unroll: head n re-runs the block over the whole
sequence with inputs shifted by n and hiddens from head n-1, mirroring
inference recurrence (simplification vs EAGLE-3's mixed-level attention
is documented in DESIGN.md).

Index convention (matches the serving engine): target feature f_t is the
fusion output after processing token x_t; head n at position t predicts
x_{t+n+1} and is scored against the target distribution softmax(z_p[t+n]).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import model as M
from . import verify_device as VD


@dataclasses.dataclass(frozen=True)
class DraftConfig:
    """One speculator configuration, tied to a TargetConfig."""

    arch: str  # "eagle3" | "mtp" | "medusa" | "mlp"
    target: M.TargetConfig
    k_heads: int = 6
    draft_vocab: int = 320  # truncated vocab (eagle3 only; others full)

    @property
    def name(self) -> str:
        return f"{self.arch}@{self.target.name}"

    @property
    def is_recurrent(self) -> bool:
        return self.arch in ("eagle3", "mtp")

    @property
    def fuse_dim(self) -> int:
        """Width of the fused target features consumed by the draft."""
        return self.target.feat_dim if self.arch == "eagle3" else self.target.d_model

    @property
    def out_vocab(self) -> int:
        return self.draft_vocab if self.arch == "eagle3" else self.target.vocab

    @property
    def own_head(self) -> bool:
        """MTP shares the target unembedding; everything else trains one."""
        return self.arch != "mtp"


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_draft(key, cfg: DraftConfig, dtype=jnp.float32) -> dict[str, Any]:
    d = cfg.target.d_model
    keys = jax.random.split(key, 8)
    if cfg.is_recurrent:
        p: dict[str, Any] = {
            "fc_fuse": jax.random.normal(keys[0], (cfg.fuse_dim, d), dtype)
            * (2.0 / cfg.fuse_dim) ** 0.5,
            "fc_in": jax.random.normal(keys[1], (2 * d, d), dtype)
            * (2.0 / (2 * d)) ** 0.5,
            "layer": M.layer_init(keys[2], draft_layer_cfg(cfg), dtype),
            "final_norm": jnp.ones((d,), dtype),
        }
        if cfg.arch == "mtp":
            p["norm_emb"] = jnp.ones((d,), dtype)
            p["norm_h"] = jnp.ones((d,), dtype)
        if cfg.own_head:
            p["head"] = (
                jax.random.normal(keys[3], (d, cfg.out_vocab), dtype)
                * (2.0 / d) ** 0.5
            )
        return p
    if cfg.arch == "medusa":
        heads = []
        for n in range(cfg.k_heads):
            k1, k2 = jax.random.split(keys[n % 8], 2)
            k1 = jax.random.fold_in(k1, n)
            k2 = jax.random.fold_in(k2, n)
            heads.append(
                {
                    "w1": jax.random.normal(k1, (d, d), dtype) * (2.0 / d) ** 0.5,
                    "head": jax.random.normal(k2, (d, cfg.out_vocab), dtype)
                    * (2.0 / d) ** 0.5,
                }
            )
        return {"heads": heads}
    if cfg.arch == "mlp":
        heads = []
        for n in range(cfg.k_heads):
            ks = jax.random.split(jax.random.fold_in(keys[n % 8], n), 3)
            heads.append(
                {
                    "ws": jax.random.normal(ks[0], (d, d), dtype) * (2.0 / d) ** 0.5,
                    "we": jax.random.normal(ks[1], (d, d), dtype) * (2.0 / d) ** 0.5,
                    "head": jax.random.normal(ks[2], (d, cfg.out_vocab), dtype)
                    * (2.0 / d) ** 0.5,
                }
            )
        return {"heads": heads, "norm": jnp.ones((d,), dtype)}
    raise ValueError(cfg.arch)


def _dense_layer_cfg(tcfg: M.TargetConfig) -> M.TargetConfig:
    """EAGLE draft blocks are always DENSE, even for MoE targets (paper
    App. E: d_ffn = num_experts_per_tok × d_expert)."""
    if tcfg.n_experts == 0:
        return tcfg
    ffn_mult = 2 * tcfg.expert_mult  # top-2 × per-expert intermediate
    return dataclasses.replace(tcfg, n_experts=0, ffn_mult=ffn_mult)


def draft_layer_cfg(cfg: DraftConfig) -> M.TargetConfig:
    """Layer config for the draft block. EAGLE-3 uses a dense block even on
    MoE targets; the MTP module retains the target's (possibly MoE)
    architecture (paper §5.2)."""
    if cfg.arch == "mtp":
        return cfg.target
    return _dense_layer_cfg(cfg.target)


def init_mtp_from_target(tparams) -> dict[str, Any]:
    """The MTP speculator's parameters ARE the target's pretrained MTP
    module (paper: fine-tune the released module). Restructure into the
    recurrent-draft layout (fc_fuse <- proj, etc.)."""
    mtp = tparams["mtp"]
    return {
        "fc_fuse": jnp.eye(mtp["proj"].shape[1], dtype=mtp["proj"].dtype),
        "fc_in": mtp["proj"],
        "norm_emb": mtp["norm_emb"],
        "norm_h": mtp["norm_h"],
        "layer": mtp["layer"],
        "final_norm": mtp["final_norm"],
    }


# ---------------------------------------------------------------------------
# recurrent drafts (EAGLE-3 / MTP): block application
# ---------------------------------------------------------------------------

def _recurrent_input(dparams, cfg: DraftConfig, tok_emb, h_prev):
    """fc_in(concat(emb, h_prev)) with MTP's extra input norms."""
    if cfg.arch == "mtp":
        tok_emb = M.rmsnorm(tok_emb, dparams["norm_emb"])
        h_prev = M.rmsnorm(h_prev, dparams["norm_h"])
    z = jnp.concatenate([tok_emb, h_prev], axis=-1)
    return z @ dparams["fc_in"]


def _draft_head(dparams, tparams, cfg: DraftConfig, h):
    hn = M.rmsnorm(h, dparams["final_norm"])
    w = dparams["head"] if cfg.own_head else tparams["head"]
    return hn @ w


def draft_extend(
    dparams,
    tparams,
    dkv: jax.Array,
    feats: jax.Array,
    tokens_next: jax.Array,
    pos,
    cfg: DraftConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Process T accepted positions through the draft block (recurrent
    archs). Used both as prompt prefill (pos=0, T=Sp) and as the
    post-verification extension (T=K+1).

    Args:
      dkv: [2, B, H, Smax, Dh] draft KV cache
      feats: [B, T, F] target fusion features for the positions
      tokens_next: [B, T] token following each position (x_{t+1})
      pos: absolute position of feats[:, 0]

    Returns (q_logits [B, T, Vd], h [B, T, d], dkv').
    The engine picks index n_acc-1 from q_logits/h for the next round.
    """
    lcfg = draft_layer_cfg(cfg)
    g0 = feats @ dparams["fc_fuse"]
    emb = jnp.take(tparams["embed"], tokens_next, axis=0)
    x = _recurrent_input(dparams, cfg, emb, g0)
    x, kv = M.transformer_layer(
        dparams["layer"], x, lcfg, kv=(dkv[0], dkv[1]), pos=pos
    )
    logits = _draft_head(dparams, tparams, cfg, x)
    return logits, x, jnp.stack(kv)


def draft_step(
    dparams,
    tparams,
    dkv: jax.Array,
    h_prev: jax.Array,
    token: jax.Array,
    pos,
    cfg: DraftConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One autoregressive drafting step (recurrent archs).

    Unlike `draft_extend`, the recurrent state input is the previous
    draft-block hidden (EAGLE recurrence) and is fed to fc_in DIRECTLY —
    no fc_fuse, which only applies to target features.

    Args:
      h_prev: [B, d] previous draft hidden (from `draft_extend` outputs at
        the last accepted index, or from the previous `draft_step`)
      token: [B] the most recent drafted token

    Returns (q_logits [B, Vd], h [B, d], dkv').
    """
    lcfg = draft_layer_cfg(cfg)
    emb = jnp.take(tparams["embed"], token, axis=0)  # [B, d]
    x = _recurrent_input(dparams, cfg, emb, h_prev)[:, None, :]  # [B, 1, d]
    x, kv = M.transformer_layer(
        dparams["layer"], x, lcfg, kv=(dkv[0], dkv[1]), pos=pos
    )
    logits = _draft_head(dparams, tparams, cfg, x)
    return logits[:, 0], x[:, 0], jnp.stack(kv)


def draft_tree_step(
    dparams,
    tparams,
    dkv: jax.Array,
    h_prev: jax.Array,
    h_all: jax.Array,
    tokens: jax.Array,
    pos,
    parents: jax.Array,
    cfg: DraftConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One LEVEL-PARALLEL tree-expansion pass (recurrent archs).

    The multi-candidate analog of `draft_step`: every candidate node of a
    per-round tree runs through the draft block in ONE pass with tree
    attention — node `i` sits at draft-KV slot `pos + i`, attends the
    committed prefix plus its own root path within the block, and takes
    RoPE position `pos + level(i)` (exactly the positions a chain of
    `draft_step` calls would use along that path). The EAGLE recurrence
    is preserved per PATH: node `i`'s input hidden is its PARENT's output
    hidden, gathered in-graph from `h_all` (root children take the
    round's `h_prev`), so the engine expands one tree level per call —
    call `c` produces valid q/h for every node at level `<= c`, and
    `depth - 1` calls expand the whole tree.

    Args:
      dkv: [2, B, H, Smax, Dh] draft KV cache; ALL node slots are
        rewritten each call (junk for not-yet-sampled levels is attended
        by nobody: a node only attends its ancestors, which are valid)
      h_prev: [B, d] the round's conditioning hidden (accepted boundary)
      h_all: [B, N, d] previous call's per-node hiddens (zeros on call 0)
      tokens: [B, N] candidate token per node (levels sampled so far)
      pos: [B] absolute draft position of node slot 0
      parents: [N] i32 node parents (-1 = root child; padding slots are
        self-parents, making them inert in mask and depth)

    Returns (q_logits [B, N, Vd], h [B, N, d], dkv'). A chain topology
    reproduces the `draft_step` chain: causal mask, positions pos+i, and
    the same per-node inputs (tested in tests/test_recurrent_tree.py).
    """
    lcfg = draft_layer_cfg(cfg)
    n = tokens.shape[1]
    idx = jnp.arange(n, dtype=jnp.int32)
    p_self = jnp.where(parents < 0, idx, parents)
    anc, depth = VD.tree_block_topology(p_self, n)
    h_par = jnp.take(h_all, jnp.clip(parents, 0, n - 1), axis=1)  # [B,N,d]
    h_nodes = jnp.where((parents < 0)[None, :, None], h_prev[:, None, :], h_par)
    emb = jnp.take(tparams["embed"], tokens, axis=0)
    x = _recurrent_input(dparams, cfg, emb, h_nodes)
    x, kv = M.transformer_layer(
        dparams["layer"], x, lcfg, kv=(dkv[0], dkv[1]), pos=pos, tree=(anc, depth)
    )
    logits = _draft_head(dparams, tparams, cfg, x)
    return logits, x, jnp.stack(kv)


def draft_tree_propose(
    dparams,
    tparams,
    dkv: jax.Array,
    h_prev: jax.Array,
    tok0: jax.Array,
    q0: jax.Array,
    u: jax.Array,
    parents: jax.Array,
    ranks: jax.Array,
    pos,
    temp,
    mode,
    cfg: DraftConfig,
    vocab_map: jax.Array | None,
    full_vocab: int,
    n_tree: int,
) -> tuple[jax.Array, list[jax.Array], jax.Array]:
    """The whole level-parallel tree expansion in one graph (device-path
    tree proposal for recurrent archs; lowered as
    `propose_tree_sample_b{B}`).

    Node 0 is the previous extend's in-graph first draft (`tok0` with
    distribution `q0`, both device-resident); its level-0 siblings sample
    from the same `q0`; each deeper level samples from its parent's
    `draft_tree_step` distribution — all through host-fed per-node
    uniforms `u [B, N]` (slot 0 unused: the host drew node 0's uniform at
    the previous advance, exactly the chain convention). Runs `n_tree-1`
    level passes unconditionally so one lowered graph serves every
    topology; a node at level L is filled at pass L-1 and later passes
    leave it unchanged.

    Returns (tokens [B, N] i32, [N] full-vocab q tensors, dkv').
    """
    idx = jnp.arange(n_tree, dtype=jnp.int32)
    p_self = jnp.where(parents < 0, idx, parents)
    _, levels = VD.tree_block_topology(p_self, n_tree)
    is_root = parents < 0
    toks, qs = [], []
    for i in range(n_tree):
        t_i = VD.tree_root_sample(q0, u[:, i], ranks[i], mode, n_tree)
        if i == 0:
            t_i = tok0
        toks.append(jnp.where(is_root[i], t_i, jnp.zeros_like(t_i)))
        qs.append(q0)
    tokens = jnp.stack(toks, axis=1)  # [B, N]
    d = h_prev.shape[-1]
    h_all = jnp.zeros((tokens.shape[0], n_tree, d), q0.dtype)
    dkv_c = dkv
    for step in range(n_tree - 1):
        qlog, h_all, dkv_c = draft_tree_step(
            dparams, tparams, dkv_c, h_prev, h_all, tokens, pos, parents, cfg
        )
        qlog_par = jnp.take(qlog, jnp.clip(parents, 0, n_tree - 1), axis=1)
        new_toks = []
        for i in range(n_tree):
            t_i, q_i = VD.tree_child_sample(
                qlog_par[:, i], u[:, i], ranks[i], temp, mode,
                vocab_map, full_vocab, n_tree,
            )
            live = levels[i] == step + 1
            qs[i] = jnp.where(live, q_i, qs[i])
            new_toks.append(jnp.where(live, t_i, tokens[:, i]))
        tokens = jnp.stack(new_toks, axis=1)
    return tokens, qs, dkv_c


def dkv_path_gather(
    dkv: jax.Array, sel: jax.Array, dst0: jax.Array
) -> jax.Array:
    """Draft-side path splice (recurrent archs): per row, gather the
    draft-KV entries at absolute positions `sel [B, N]` and scatter them
    linearly from `dst0 [B]` — the [2, B, H, Smax, Dh]-layout twin of the
    target's `kv_path_gather`. Gathers read the pre-update cache; batch
    rows never overlap. Lowered per bucket as `dkv_path_gather_b{B}`.
    """
    b = dkv.shape[1]
    out = dkv
    for bi in range(b):  # B <= 4; unrolled per-row
        g = jnp.take(dkv[:, bi], sel[bi], axis=2)  # [2, H, N, Dh]
        out = jax.lax.dynamic_update_slice(
            out, g[:, None], (0, bi, 0, dst0[bi], 0)
        )
    return out


def draft_train_unroll(
    dparams,
    tparams,
    feats: jax.Array,
    tokens: jax.Array,
    cfg: DraftConfig,
) -> jax.Array:
    """Training-time-test unroll for recurrent drafts.

    Args:
      feats: [B, S, F] target features (frozen) for positions 0..S-1
      tokens: [B, S+K] ground-truth tokens x_0..x_{S+K-1}

    Head n (1-indexed) at position t consumes embed(x_{t+n}) and the
    previous head's hidden g^{n-1}_t, predicting x_{t+n+1}.

    Returns q_logits [K, B, S, Vd].
    """
    k = cfg.k_heads
    s = feats.shape[1]
    lcfg = draft_layer_cfg(cfg)
    g = feats @ dparams["fc_fuse"]  # g^0
    out = []
    for n in range(1, k + 1):
        tok_n = jax.lax.dynamic_slice_in_dim(tokens, n, s, axis=1)  # x_{t+n}
        emb = jnp.take(tparams["embed"], tok_n, axis=0)
        x = _recurrent_input(dparams, cfg, emb, g)
        x, _ = M.transformer_layer(dparams["layer"], x, lcfg)
        out.append(_draft_head(dparams, tparams, cfg, x))
        g = x
    return jnp.stack(out)


# ---------------------------------------------------------------------------
# MEDUSA
# ---------------------------------------------------------------------------

def medusa_propose(dparams, hidden: jax.Array, cfg: DraftConfig) -> jax.Array:
    """All K head logits from the last hidden state.

    hidden: [B, d] (or [B, S, d] during training) -> [K, B(, S), V].
    Head n: h' = h + SiLU(W1 h); logits = h' @ head  (residual MLP block).
    """
    outs = []
    for hp in dparams["heads"]:
        hprime = hidden + jax.nn.silu(hidden @ hp["w1"])
        outs.append(hprime @ hp["head"])
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# MLP speculator
# ---------------------------------------------------------------------------

def mlp_step(
    dparams, tparams, state: jax.Array, token: jax.Array, head_idx, cfg: DraftConfig
) -> tuple[jax.Array, jax.Array]:
    """One MLP-speculator stage: state' = SiLU(Ws state + We emb(token)).

    head_idx selects the per-position weights (scalar; staged weights are
    stacked so one lowered artifact serves all K steps).
    """
    ws = jnp.stack([h["ws"] for h in dparams["heads"]])  # [K, d, d]
    we = jnp.stack([h["we"] for h in dparams["heads"]])
    wh = jnp.stack([h["head"] for h in dparams["heads"]])
    ws_n = jax.lax.dynamic_index_in_dim(ws, head_idx, keepdims=False)
    we_n = jax.lax.dynamic_index_in_dim(we, head_idx, keepdims=False)
    wh_n = jax.lax.dynamic_index_in_dim(wh, head_idx, keepdims=False)
    emb = jnp.take(tparams["embed"], token, axis=0)
    new_state = jax.nn.silu(state @ ws_n + emb @ we_n)
    logits = M.rmsnorm(new_state, dparams["norm"]) @ wh_n
    return logits, new_state


def mlp_train_unroll(
    dparams, tparams, hidden: jax.Array, tokens: jax.Array, cfg: DraftConfig
) -> jax.Array:
    """Teacher-forced MLP speculator unroll.

    hidden: [B, S, d] last-layer target hiddens; tokens [B, S+K].
    state_0 = hidden_t; stage n consumes x_{t+n}; logits_n predict x_{t+n+1}.
    Returns [K, B, S, V].
    """
    s = hidden.shape[1]
    state = hidden
    outs = []
    for n in range(1, cfg.k_heads + 1):
        hp = dparams["heads"][n - 1]
        tok_n = jax.lax.dynamic_slice_in_dim(tokens, n, s, axis=1)
        emb = jnp.take(tparams["embed"], tok_n, axis=0)
        state = jax.nn.silu(state @ hp["ws"] + emb @ hp["we"])
        outs.append(M.rmsnorm(state, dparams["norm"]) @ hp["head"])
    return jnp.stack(outs)
