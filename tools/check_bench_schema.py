#!/usr/bin/env python3
"""Bench-JSON schema smoke: `results/BENCH_engine.json` is the
machine-readable perf ledger CI uploads per run; downstream trend
tooling (and docs/METRICS.md, which documents the row shapes) depend on
its keys staying put. This guard fails CI when a bench section drops a
required key or emits a non-numeric value — so the artifact and the
docs that describe it can't drift silently.

Usage: check_bench_schema.py [BENCH_engine.json]
       (defaults to rust/results/BENCH_engine.json next to this script)
"""

import json
import sys
from pathlib import Path

# Per-bench required numeric keys (every row additionally carries the
# string discriminators "bench" and "config").
SCHEMAS: dict[str, set[str]] = {
    "speculation_controller": {
        "tok_s",
        "tokens",
        "rounds",
        "rounds_per_token",
        "sim_cost_per_token",
        "padded_row_rounds",
        "downshifts",
        "accepted_len_mean",
        "bytes_to_host",
    },
    "verify_transfer_analytic": {"bytes_to_host"},
    "verify_transfer_live": {"rounds", "accepted_len_mean", "bytes_to_host"},
    "end_to_end": {"tok_s", "vanilla_tok_s", "tau"},
    "paged_kv_capacity": {
        "block_budget",
        "capacity_dense",
        "capacity_paged",
        "capacity_ratio",
        "prefix_hit_rate",
    },
    "kv_migration_analytic": {
        "host_kv_bytes_host_repack",
        "host_kv_bytes_device",
    },
    "chaos_smoke": {
        "sessions",
        "sessions_lost",
        "faults_injected",
        "rounds",
        "transient_retries",
        "rounds_to_recover",
    },
    "http_stream_latency": {
        "requests",
        "tokens",
        "events",
        "ttft_ms_p50",
        "inter_token_ms_p50",
    },
    "prefill_interference": {
        "short_ttft_p50",
        "short_ttft_p99",
        "long_ttft_p50",
        "long_ttft_p99",
        "decode_gap_p50",
        "decode_gap_p99",
        "prefill_chunks",
        "prefill_tokens_saved",
    },
    "adaptation_drift": {
        "sessions",
        "rounds",
        "records_harvested",
        "swaps",
        "trainer_runs",
        "alpha_hat_pre",
        "alpha_hat_post",
        "alpha_gain",
    },
}

# Sections that must be present in EVERY run (artifact-less CI included;
# the live/end-to-end sections only appear when checkpoints exist).
ALWAYS_PRESENT = {
    "speculation_controller",
    "verify_transfer_analytic",
    "paged_kv_capacity",
    "kv_migration_analytic",
    "chaos_smoke",
    "http_stream_latency",
    "prefill_interference",
    "adaptation_drift",
}


def check(path: Path) -> list[str]:
    errors: list[str] = []
    try:
        rows = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(rows, list) or not rows:
        return [f"{path}: expected a non-empty JSON array of rows"]
    seen: set[str] = set()
    for i, row in enumerate(rows):
        where = f"{path}: row {i}"
        if not isinstance(row, dict):
            errors.append(f"{where}: not an object")
            continue
        bench = row.get("bench")
        if not isinstance(bench, str):
            errors.append(f"{where}: missing string key 'bench'")
            continue
        if not isinstance(row.get("config"), str):
            errors.append(f"{where} ({bench}): missing string key 'config'")
        required = SCHEMAS.get(bench)
        if required is None:
            errors.append(f"{where}: unknown bench '{bench}' (update SCHEMAS)")
            continue
        seen.add(bench)
        for key in sorted(required):
            v = row.get(key)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                errors.append(f"{where} ({bench}): key '{key}' missing or non-numeric")
    for bench in sorted(ALWAYS_PRESENT - seen):
        errors.append(f"{path}: no rows from always-on section '{bench}'")
    return errors


def main() -> int:
    default = Path(__file__).resolve().parent.parent / "rust/results/BENCH_engine.json"
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else default
    errors = check(path)
    for e in errors:
        print(e)
    print(f"checked {path}: {'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
