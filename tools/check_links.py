#!/usr/bin/env python3
"""Markdown link check: every relative link/image target in the given
markdown files must exist on disk (anchors are stripped; http(s)/mailto
links are skipped). Exits non-zero listing the broken ones — the CI
guard that keeps README/DESIGN/ROADMAP from rotting silently.

Usage: check_links.py [FILE.md ...]   (defaults to the repo's top-level
markdown files, resolved relative to this script's parent directory)
"""

import re
import sys
from pathlib import Path

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
DEFAULT = [
    "README.md",
    "DESIGN.md",
    "ROADMAP.md",
    "PAPER.md",
    "PAPERS.md",
    "CHANGES.md",
    "docs/METRICS.md",
]


def check(md: Path) -> list[str]:
    broken = []
    for n, line in enumerate(md.read_text().splitlines(), 1):
        for m in LINK.finditer(line):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, …
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure in-page anchor
                continue
            if not (md.parent / path).exists():
                broken.append(f"{md}:{n}: broken link -> {target}")
    return broken


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    files = [Path(a) for a in sys.argv[1:]] or [
        root / f for f in DEFAULT if (root / f).exists()
    ]
    broken = []
    for f in files:
        if not f.exists():
            broken.append(f"{f}: file missing")
            continue
        broken.extend(check(f))
    for b in broken:
        print(b)
    print(f"checked {len(files)} files: {'FAIL' if broken else 'ok'}")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
